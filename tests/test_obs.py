"""repro.obs: Prometheus exposition invariants, Chrome-trace validity and
determinism, decision-audit ring semantics — and the cardinal rule that
full observability must not perturb scheduling (the golden dispatch logs
stay bit-exact with tracing, metrics, and auditing all on)."""
import copy
import importlib.util
import json
import os
import pathlib

import pytest

from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.memory import AnalyticMemoryEstimator, LLAMA2_13B_DELTA
from repro.core.schedulers import make_strategy
from repro.obs import (NULL_TRACER, OBS_OFF, DecisionLog, MetricsRegistry,
                       Observability, Tracer, decisions_path_for, worker_tid)
from repro.serving import ServingConfig, default_sim_environment

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_batch_compositions.json")

# the CI validator doubles as the test-suite definition of "valid"
_spec = importlib.util.spec_from_file_location(
    "validate_obs",
    pathlib.Path(__file__).parent.parent / "scripts" / "validate_obs.py")
validate_obs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(validate_obs)


# ---------------------------------------------------------------------------
# pillar 2: Prometheus metrics
# ---------------------------------------------------------------------------
def test_prometheus_render_invariants():
    reg = MetricsRegistry()
    c = reg.counter("demo_requests", "Requests served", ("outcome",))
    g = reg.gauge("demo_depth", "Queue depth")
    h = reg.histogram("demo_latency_seconds", "Latency",
                      buckets=(0.1, 1.0, 10.0))
    c.inc(outcome="ok")
    c.inc(2, outcome="err")
    g.set(7)
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    text = reg.render()
    assert text.endswith("\n")
    # counters get the _total suffix; TYPE lines precede samples
    assert "# TYPE demo_requests_total counter" in text
    assert 'demo_requests_total{outcome="err"} 2' in text
    assert 'demo_requests_total{outcome="ok"} 1' in text
    assert "# TYPE demo_depth gauge" in text and "demo_depth 7" in text
    # histogram: cumulative buckets ending in +Inf == _count, plus _sum
    assert 'demo_latency_seconds_bucket{le="0.1"} 1' in text
    assert 'demo_latency_seconds_bucket{le="1"} 3' in text
    assert 'demo_latency_seconds_bucket{le="10"} 4' in text
    assert 'demo_latency_seconds_bucket{le="+Inf"} 5' in text
    assert "demo_latency_seconds_count 5" in text
    assert "demo_latency_seconds_sum 56.05" in text
    # the CI validator agrees end to end
    assert validate_obs.validate_prometheus(text) == []
    fams = validate_obs.parse_prometheus(text)
    assert fams["demo_latency_seconds"]["type"] == "histogram"
    assert fams["demo_requests_total"]["samples"][
        'demo_requests_total{outcome="err"}'] == 2


def test_metric_declaration_and_observation_errors():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "x", ("a",))
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, a="v")
    with pytest.raises(ValueError, match="labels"):
        c.inc(b="wrong-label")
    # idempotent re-declaration returns the same object...
    assert reg.counter("x_total", "x", ("a",)) is c
    # ...but a type or label change is a hard error
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("x_total", "x")
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("x_total", "x", ("a", "b"))
    with pytest.raises(ValueError, match="strictly increasing"):
        reg.histogram("h", "h", buckets=(1.0, 1.0))
    # declared name already ending in _total is not doubled
    assert c.sample_name == "x_total"


# ---------------------------------------------------------------------------
# pillar 1: Chrome trace events
# ---------------------------------------------------------------------------
def _demo_tracer():
    t = [0.0]
    tr = Tracer(clock=lambda: t[0])
    tr.declare_worker(0)
    tr.instant("arrival", 0.5, args=dict(rid=1))
    tr.async_begin("request", 1, 0.5)
    tr.counter("queue_depth", 3, ts=0.6)
    tr.complete("slice", 1.0, 0.25, tid=worker_tid(0),
                args=dict(rids=[1], input_len=8, slice_len=4))
    tr.async_end("request", 1, 2.0, args=dict(outcome="completed"))
    return tr


def test_tracer_emits_valid_chrome_trace_json():
    tr = _demo_tracer()
    obj = json.loads(tr.to_json())   # round-trips through real JSON
    assert validate_obs.validate_trace(obj) == []
    events = obj["traceEvents"]
    # metadata names both processes and the declared tracks
    meta = [e for e in events if e["ph"] == "M"]
    names = {(e["pid"], e.get("tid"), e["name"]): e["args"] for e in meta}
    assert names[(1, 0, "process_name")]["name"] == "scheduler"
    assert names[(2, 0, "process_name")]["name"] == "requests"
    assert names[(1, worker_tid(0), "thread_name")]["name"] == "worker 0"
    # timestamps are microseconds of the second-denominated inputs
    span = next(e for e in events if e["ph"] == "X")
    assert span["ts"] == 0.5e6 + 0.5e6 and span["dur"] == 0.25e6
    assert span["tid"] == worker_tid(0)
    # the standalone validator's mirrored track constant stays in sync
    assert validate_obs.TID_WORKER_BASE == worker_tid(0)


def test_tracer_serialization_is_deterministic():
    assert _demo_tracer().to_json() == _demo_tracer().to_json()


def test_validator_flags_unbalanced_async_spans():
    tr = _demo_tracer()
    tr.async_begin("request", 99, 3.0)   # opened, never finalized
    errs = validate_obs.validate_trace(tr.to_dict())
    assert any("never closed" in e and "99" in e for e in errs)


def test_null_tracer_and_shared_off_bundle_record_nothing():
    assert not NULL_TRACER.enabled and len(NULL_TRACER) == 0
    NULL_TRACER.instant("x")
    NULL_TRACER.complete("y", 0.0, 1.0)
    NULL_TRACER.counter("z", 1)
    assert len(NULL_TRACER) == 0
    assert not OBS_OFF.enabled
    assert OBS_OFF.registry is None and OBS_OFF.audit is None
    # a bare core gets the shared disabled bundle, not a fresh one
    true_lat, est, mem = default_sim_environment("hf")
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        true_lat, est, mem)
    # ServingConfig servers get standard() obs: metrics + audit on,
    # tracing only with --trace-out
    assert server.core.obs.enabled
    assert server.core.obs.tracer is NULL_TRACER
    bare = ClusterSimulator(make_strategy("scls"), 2, true_lat, est, mem)
    assert bare.core.obs is OBS_OFF


def test_every_hook_site_is_guarded():
    """Overhead discipline: the hot path pays one attribute read + bool
    test per hook point when observability is off — every ``*.obs.on_*``
    call site sits behind a ``*.obs.enabled`` guard.  Checked repo-wide
    by the obs-guard static-analysis pass (which replaced the old
    string-count assertion: it pins the exact unguarded site instead of
    comparing substring tallies in one module)."""
    from repro.analysis import run_analysis
    report = run_analysis(rules=["obs-guard"])
    assert report.ok, "\n" + report.render()
    assert report.n_files > 50  # scanned all of src/repro, not one module


# ---------------------------------------------------------------------------
# pillar 3: decision audit
# ---------------------------------------------------------------------------
def test_decision_log_ring_and_query():
    log = DecisionLog(capacity=4)
    for i in range(10):
        log.record("batch" if i % 2 else "offload", ts=float(i),
                   rids=[i, 100 + i], worker=i % 3)
    assert len(log) == 4 and log.n_recorded == 10
    kept = log.to_list()
    assert [e["seq"] for e in kept] == [6, 7, 8, 9]  # oldest dropped
    # kind filter
    assert all(e["kind"] == "batch" for e in log.query(kind="batch"))
    # rid matches membership in ``rids`` and exact ``rid`` fields
    assert [e["seq"] for e in log.query(rid=107)] == [7]
    log.record("admission", ts=11.0, rid=42, action="reject")
    assert [e["kind"] for e in log.query(rid=42)] == ["admission"]
    # limit keeps the newest N, oldest-first
    assert [e["seq"] for e in log.query(limit=2)] == [9, 10]
    with pytest.raises(ValueError, match="capacity"):
        DecisionLog(capacity=0)


# ---------------------------------------------------------------------------
# the cardinal rule: zero scheduling perturbation
# ---------------------------------------------------------------------------
def _golden_cases():
    with open(GOLDEN) as f:
        g = json.load(f)
    # one static-mode run and one continuous-mode run, both with noise —
    # the RNG-sensitive paths where an accidental extra draw would show
    want = {("scls", 0.05), ("scls-cb", 0.05)}
    return [pytest.param(g["scenario_args"], r,
                         id=f"{r['strategy']}-sigma{r['noise_sigma']}")
            for r in g["runs"]
            if (r["strategy"], r["noise_sigma"]) in want]


@pytest.mark.parametrize("args, want", _golden_cases())
def test_golden_dispatch_log_bit_exact_with_full_observability(args, want):
    """Tentpole acceptance: the golden batch compositions recorded before
    ``repro.obs`` existed are reproduced bit-for-bit with tracing, metrics,
    and decision auditing all enabled — and the trace's dispatch spans
    reconstruct that exact log (every slice a span with matching rid set,
    worker track, and slice geometry)."""
    from repro.core.estimator import a100_llama13b_profile
    from repro.core.memory import A100_80GB_AVAILABLE
    from repro.serving import fitted_estimator
    true_lat = a100_llama13b_profile()
    est = fitted_estimator(true_lat, seed=0)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=A100_80GB_AVAILABLE, zeta=0.9)
    trace = generate_trace(args["rate"], args["duration"], CODEFUSE,
                           seed=args["trace_seed"])
    s = make_strategy(want["strategy"], slice_len=args["slice_len"],
                      fixed_batch_size=args["fixed_batch_size"],
                      gamma=args["gamma"], max_parallel=args["max_parallel"])
    sim = ClusterSimulator(s, args["workers"], true_lat, est, mem,
                           noise_sigma=want["noise_sigma"],
                           seed=args["sim_seed"])
    sim.core.obs = Observability.standard(trace=True)
    sim.core.obs.attach(sim.core)
    res = sim.run(copy.deepcopy(trace), args["duration"])
    assert res.metrics.n_completed == want["n_completed"]
    assert sim.batch_log == want["batch_log"]

    obs = sim.core.obs
    tdict = obs.tracer.to_dict()
    assert validate_obs.validate_trace(tdict) == []
    # span-by-span reconstruction of the golden dispatch log
    assert validate_obs.trace_slice_log(tdict) == want["batch_log"]
    # the metrics pillar observed the same dispatches
    assert obs.ins.slices.value() == len(want["batch_log"])
    assert obs.ins.slice_time.count() == len(want["batch_log"])
    # the audit recorded a batch + offload pair per central dispatch, with
    # the Eq. 11 loads every placement saw at decision time
    n_static = sum(1 for e in want["batch_log"] if e[0] == "static")
    if n_static:
        offloads = obs.audit.query(kind="offload")
        assert len(offloads) >= 1
        assert all(set(e["loads"]) == {str(w)
                                       for w in range(args["workers"])}
                   for e in offloads)
        batches = obs.audit.query(kind="batch")
        assert all(e["mem_bound"] >= len(e["rids"]) for e in batches)


def test_sim_slice_spans_carry_prefill_decode_phases():
    """The sim backend splits each slice span into prefill + decode
    sub-spans from the latency model's nominal ratio — without costing an
    extra RNG draw (the golden test above is the proof)."""
    true_lat, est, mem = default_sim_environment("hf")
    cfg = ServingConfig(strategy="scls", workers=2, trace_out="unused.json")
    server = cfg.build_sim(true_lat, est, mem)
    server.replay(generate_trace(2.0, 10.0, CODEFUSE, seed=3))
    server.drain()
    events = server.core.obs.tracer.to_dict()["traceEvents"]
    slices = [e for e in events if e["ph"] == "X" and e["name"] == "slice"]
    prefills = [e for e in events if e["name"] == "prefill"]
    decodes = [e for e in events if e["name"] == "decode"]
    assert len(slices) >= 1
    assert len(prefills) == len(decodes) == len(slices)
    for s, p, d in zip(slices, prefills, decodes):
        assert s["ts"] == p["ts"] and s["tid"] == p["tid"] == d["tid"]
        assert 0.0 <= p["dur"] <= s["dur"]
        assert p["dur"] + d["dur"] == pytest.approx(s["dur"], abs=1e-3)


def test_same_seed_same_config_byte_identical_trace():
    """Determinism: on the sim backend nothing in the obs stack reads
    wall clocks or draws randomness, so same seed ⇒ same trace bytes."""
    def run():
        true_lat, est, mem = default_sim_environment("hf")
        cfg = ServingConfig(strategy="scls", workers=2, seed=4,
                            trace_out="unused.json")
        server = cfg.build_sim(true_lat, est, mem)
        server.replay(generate_trace(3.0, 15.0, CODEFUSE, seed=8))
        server.drain()
        assert server.core.obs.tracer.enabled
        return server.core.obs.tracer.to_json()

    a, b = run(), run()
    assert len(json.loads(a)["traceEvents"]) > 10
    assert a == b


def test_export_writes_trace_and_decisions(tmp_path):
    true_lat, est, mem = default_sim_environment("hf")
    cfg = ServingConfig(strategy="scls", workers=2,
                        trace_out=str(tmp_path / "t.json"))
    server = cfg.build_sim(true_lat, est, mem)
    server.replay(generate_trace(2.0, 8.0, CODEFUSE, seed=5))
    server.drain()
    paths = server.core.obs.export(cfg.trace_out)
    assert paths == [str(tmp_path / "t.json"),
                     str(tmp_path / "t.decisions.json")]
    assert decisions_path_for("x/trace.json") == "x/trace.decisions.json"
    with open(paths[0]) as f:
        assert validate_obs.validate_trace(json.load(f)) == []
    with open(paths[1]) as f:
        events = json.load(f)
    assert events and all({"seq", "ts", "kind"} <= set(e) for e in events)
    # the CLI entry point agrees
    metrics_file = tmp_path / "metrics.txt"
    metrics_file.write_text(server.core.obs.registry.render())
    assert validate_obs.main([paths[0], "--metrics", str(metrics_file),
                              "--decisions", paths[1]]) == 0


# ---------------------------------------------------------------------------
# per-reason rejection counts (satellite: RunMetrics + fig12)
# ---------------------------------------------------------------------------
def test_compute_metrics_carries_reject_reasons():
    from repro.cluster.metrics import compute_metrics
    m = compute_metrics("x", [], 10.0, [1.0], [1], 0, 0,
                        reject_reasons={"memory": 2, "deadline": 5})
    assert m.n_rejected_memory == 2 and m.n_rejected_deadline == 5
    row = m.row()
    assert row["n_rejected_memory"] == 2
    assert row["n_rejected_deadline"] == 5
    bare = compute_metrics("x", [], 10.0, [1.0], [1], 0, 0)
    assert bare.n_rejected_memory == bare.n_rejected_deadline == 0
