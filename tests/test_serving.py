"""repro.serving: SchedulerCore/Backend equivalence with the legacy
runtimes, the SliceServer online API (submit / stream / cancel / drain),
and ServingConfig validation."""
import copy
import itertools
import json
import os

import numpy as np
import pytest

from repro.cluster.metrics import compute_metrics
from repro.cluster.simulator import ClusterSimulator
from repro.cluster.trace import CODEFUSE, generate_trace
from repro.core.memory import (AnalyticMemoryEstimator, LLAMA2_13B_DELTA,
                               PagedMemoryEstimator)
from repro.core.request import Request
from repro.core.schedulers import make_strategy
from repro.serving import (ServingConfig, SimBackend, SchedulerCore,
                           default_sim_environment)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_batch_compositions.json")


@pytest.fixture(scope="module")
def sim_env():
    return default_sim_environment("hf")  # analytic memory model


# ---------------------------------------------------------------------------
# tentpole acceptance: one SchedulerCore, zero scheduling drift
# ---------------------------------------------------------------------------
def _golden_runs():
    with open(GOLDEN) as f:
        g = json.load(f)
    return [pytest.param(g["scenario_args"], r,
                         id=f"{r['strategy']}-sigma{r['noise_sigma']}")
            for r in g["runs"]]


@pytest.mark.parametrize("args, want", _golden_runs())
def test_scheduler_core_matches_legacy_batch_compositions(args, want):
    """The refactored SchedulerCore must reproduce the pre-refactor
    ClusterSimulator's dispatch log (which requests ran together, on which
    worker, with what slice) bit-for-bit — goldens were recorded at commit
    307a423 by scripts/gen_equivalence_golden.py."""
    from repro.core.estimator import a100_llama13b_profile
    from repro.core.memory import A100_80GB_AVAILABLE
    from repro.serving import fitted_estimator
    true_lat = a100_llama13b_profile()  # the golden generator's exact env
    est = fitted_estimator(true_lat, seed=0)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=A100_80GB_AVAILABLE, zeta=0.9)
    trace = generate_trace(args["rate"], args["duration"], CODEFUSE,
                           seed=args["trace_seed"])
    s = make_strategy(want["strategy"], slice_len=args["slice_len"],
                      fixed_batch_size=args["fixed_batch_size"],
                      gamma=args["gamma"], max_parallel=args["max_parallel"])
    sim = ClusterSimulator(s, args["workers"], true_lat, est, mem,
                           noise_sigma=want["noise_sigma"],
                           seed=args["sim_seed"])
    res = sim.run(copy.deepcopy(trace), args["duration"])
    assert res.metrics.n_completed == want["n_completed"]
    assert sim.batch_log == want["batch_log"]


def test_sim_and_real_share_one_core(sim_env):
    """Both legacy shims drive the same SchedulerCore class."""
    from repro.cluster.realtime import RealCluster
    import repro.serving.core as core_mod
    true_lat, est, mem = sim_env
    sim = ClusterSimulator(make_strategy("scls"), 2, true_lat, est, mem)
    assert type(sim.core) is core_mod.SchedulerCore
    assert RealCluster.__init__.__module__ == "repro.cluster.realtime"
    # the scheduling loop is gone from the shims
    import inspect
    import repro.cluster.simulator as sim_mod
    import repro.cluster.realtime as real_mod
    for mod in (sim_mod, real_mod):
        src = inspect.getsource(mod)
        for needle in ("dp_batch", "_on_tick", "next_interval", "heappush"):
            assert needle not in src, f"{mod.__name__} still has {needle}"


# ---------------------------------------------------------------------------
# SliceServer online API (sim backend)
# ---------------------------------------------------------------------------
def test_slice_server_streams_tokens_per_slice(sim_env):
    true_lat, est, mem = sim_env
    cfg = ServingConfig(strategy="scls", workers=2, slice_len=64, gamma=1.0)
    server = cfg.build_sim(true_lat, est, mem)
    # staggered submissions: the second arrives while the first is in flight
    h1 = server.submit(input_len=100, gen_len=200, arrival=0.0)
    h2 = server.submit(input_len=40, gen_len=30, arrival=2.0)
    stream = h1.tokens()
    first = list(itertools.islice(stream, 70))
    assert first == list(range(70))          # sim tokens = generation indices
    assert not h1.finished                   # 200 > 70: still generating
    assert h1.request.n_schedules >= 2       # 70 tokens needed >= 2 slices
    rest = list(stream)
    assert first + rest == list(range(200))
    assert h1.done and h1.request.generated == 200
    assert h2.result().done                  # driving h1 served h2 too
    m = server.drain()
    assert m.n_completed == 2
    assert m.ttft_mean > 0 and m.p99_response >= m.p95_response >= m.p50_response


def test_slice_server_throughput_matches_legacy_run(sim_env):
    """Replaying a trace through the online API matches the offline
    ``run()`` path within tolerance (tick phase differs slightly: online
    ticks start at first arrival, offline at t=0)."""
    true_lat, est, mem = sim_env
    trace = generate_trace(8.0, 60.0, CODEFUSE, seed=11)
    legacy = ClusterSimulator(make_strategy("scls"), 4, true_lat, est, mem,
                              seed=3).run(copy.deepcopy(trace), 60.0).metrics
    cfg = ServingConfig(strategy="scls", workers=4, seed=3)
    server = cfg.build_sim(true_lat, est, mem)
    server.replay(copy.deepcopy(trace))
    online = server.drain(60.0)
    assert online.n_completed == legacy.n_completed == len(trace)
    assert online.throughput == pytest.approx(legacy.throughput, rel=0.1)
    assert online.mean_response == pytest.approx(legacy.mean_response, rel=0.2)


def test_cancel_pending_lease_decays_offloader_load(sim_env):
    """Regression: a SCLS-CB lease cancelled while still pending on a
    worker must return its marginal load charge to the offloader — a
    leaked charge would skew max-min placement and Eq. 12 forever."""
    true_lat, est, _ = sim_env
    # token budget fits one (64+64)-token lease but not two, so the second
    # lease waits in the worker's pending queue (exact Eq. 5/9 admission)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=170e6, zeta=0.9)
    cfg = ServingConfig(strategy="scls-cb", workers=1, slice_len=64,
                        gamma=1.0)
    server = cfg.build_sim(true_lat, est, mem)
    blocker = server.submit(input_len=64, gen_len=600)
    victim = server.submit(input_len=64, gen_len=600, arrival=0.1)
    while not any(r.rid == victim.rid
                  for w in server.core.workers for r in w.pending):
        assert server.step(), "victim never queued behind the blocker"
    assert victim.cancel()
    assert victim.cancelled and victim.finished
    assert victim.rid not in server.core._lease_est
    server.drain()
    assert blocker.done
    assert not server.core._lease_est
    assert max(server.core.offloader.loads.values()) == pytest.approx(
        0.0, abs=1e-12)


def test_cancel_before_any_generation_does_not_train_predictor(sim_env):
    """Regression: a request cancelled with generated == 0 carries no
    length evidence; recording it would log a phantom 1-token completion
    and bias calibrated caps toward zero."""
    true_lat, est, mem = sim_env
    cfg = ServingConfig(strategy="scls-pred", predictor="histogram",
                        workers=2)
    server = cfg.build_sim(true_lat, est, mem)
    h = server.submit(input_len=64, gen_len=200)
    h.cancel()
    server.drain()
    assert h.cancelled and h.request.generated == 0
    assert server.core.predictor.n_observed == 0


def test_cancel_from_pool_is_immediate(sim_env):
    true_lat, est, mem = sim_env
    cfg = ServingConfig(strategy="scls", workers=2)
    server = cfg.build_sim(true_lat, est, mem)
    h = server.submit(input_len=64, gen_len=500)
    assert h.cancel()
    assert h.finished and h.cancelled and not h.done
    assert h.request.generated == 0
    assert h.cancel()  # idempotent: still reports cancelled
    m = server.drain()
    assert m.n_completed == 0


def test_cancel_mid_flight_sim_backend_frees_blocks_and_trains_predictor():
    """Cancel during a slice on the sim backend: pages (continuous block
    charges) return to baseline and the predictor records the truncated
    length — the online-admission contract of the serving API."""
    # (a) scls-cb + paged: block charges on the workers must vanish
    true_lat, est, _ = default_sim_environment("hf")
    mem = PagedMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                               m_available=5e9, zeta=0.9, page_tokens=16)
    cfg = ServingConfig(strategy="scls-cb", kv_layout="paged", workers=2,
                        slice_len=64, gamma=1.0)
    server = cfg.build_sim(true_lat, est, mem)
    victim = server.submit(input_len=64, gen_len=600)
    others = [server.submit(input_len=32 + i, gen_len=100, arrival=0.5)
              for i in range(4)]
    while not victim.finished and victim.request.generated == 0:
        server.step()
    assert not victim.finished, "victim finished before it could be cancelled"
    victim.cancel()
    m = server.drain()
    assert victim.cancelled and not victim.done
    assert 0 < victim.request.generated < 600  # truncated mid-generation
    assert all(h.done for h in others)
    assert all(not w.running and not w.pending for w in server.core.workers)
    assert m.n_completed == 4

    # (b) scls-pred: the prediction pipeline must see the truncated length
    mem2 = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                   m_available=5e9, zeta=0.9)
    cfg2 = ServingConfig(strategy="scls-pred", predictor="histogram",
                         workers=2, slice_len=64, gamma=1.0)
    server2 = cfg2.build_sim(true_lat, est, mem2)
    victim2 = server2.submit(input_len=64, gen_len=600)
    for i in range(4):
        server2.submit(input_len=32 + i, gen_len=100, arrival=0.5)
    while not victim2.finished and victim2.request.generated == 0:
        server2.step()
    victim2.cancel()
    server2.drain()
    assert victim2.cancelled and 0 < victim2.request.generated < 600
    # every terminal request (4 completed + 1 truncated) trained the online
    # predictor; the cancelled one contributed its realized length
    assert server2.core.predictor.n_observed == 5


def test_submit_before_armed_future_tick_is_not_starved(sim_env):
    """Regression: a far-future submission arms a tick at its arrival;
    a later submission with an EARLIER arrival must re-arm the tick at
    its own time instead of waiting for the future one."""
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2, gamma=1.0).build_sim(
        true_lat, est, mem)
    late = server.submit(input_len=32, gen_len=20, arrival=100.0)
    early = server.submit(input_len=32, gen_len=20, arrival=0.0)
    early.result()
    assert early.request.first_token_time < 50.0
    server.drain()
    assert late.done and late.request.first_token_time >= 100.0


def test_build_sim_partial_args_stay_consistent(sim_env):
    """Regression: omitting only mem must not silently pair the caller's
    latency models with the DS rule table (nor refit a discarded default
    environment); the analytic A100 model is the partial-args default."""
    from repro.core.estimator import a100_llama13b_hf_profile
    from repro.serving import fitted_estimator
    hf_lat = a100_llama13b_hf_profile()
    hf_est = fitted_estimator(hf_lat)
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        hf_lat, hf_est)
    assert isinstance(server.core.mem, AnalyticMemoryEstimator)
    assert server.core.backend.true_lat is hf_lat
    assert server.core.est is hf_est
    # paged configs get the paged pool instead
    paged = ServingConfig(strategy="scls-cb", kv_layout="paged",
                          workers=2).build_sim(hf_lat, hf_est)
    assert isinstance(paged.core.mem, PagedMemoryEstimator)


def test_submit_then_replay_no_rid_collision(sim_env):
    """Interactive submits use their own rid namespace, so mixing them
    with trace replay (rids 0..n) on one server must not collide."""
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        true_lat, est, mem)
    h = server.submit(input_len=16, gen_len=8)
    trace = generate_trace(2.0, 10.0, CODEFUSE, seed=5)
    handles = server.replay(trace)
    m = server.drain()
    assert h.done and all(t.done for t in handles)
    assert m.n_completed == len(trace) + 1


def test_replay_and_submit_refused_after_close(sim_env):
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        true_lat, est, mem)
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(input_len=8, gen_len=4)
    with pytest.raises(RuntimeError, match="closed"):
        server.replay(generate_trace(1.0, 5.0, CODEFUSE, seed=6))


def test_drain_before_any_submission_yields_finite_metrics(sim_env):
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        true_lat, est, mem)
    m = server.drain()
    for k, v in m.row().items():
        if isinstance(v, float):
            assert np.isfinite(v), f"{k} is not finite: {v}"
    assert m.n_requests == m.n_completed == 0


def test_sim_requests_do_not_materialize_token_lists(sim_env):
    """Offline sim replays must not pay for synthetic token storage: the
    core's token log stays empty and output_tokens stays None (legacy
    behavior); streaming handles synthesize indices lazily instead."""
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2).build_sim(
        true_lat, est, mem)
    trace = generate_trace(2.0, 20.0, CODEFUSE, seed=9)
    handles = server.replay(trace)
    server.drain()
    assert not server.core.token_log
    assert all(r.output_tokens is None for r in trace)
    h = handles[0]
    assert h.output_tokens == list(range(h.request.generated))


# ---------------------------------------------------------------------------
# ServingConfig
# ---------------------------------------------------------------------------
def test_serving_config_validates_combinations():
    with pytest.raises(ValueError, match="strategy"):
        ServingConfig(strategy="nope")
    with pytest.raises(ValueError, match="prediction-aware"):
        ServingConfig(strategy="scls", predictor="histogram")
    with pytest.raises(ValueError, match="perfect"):
        ServingConfig(strategy="oracle", predictor="histogram")
    with pytest.raises(ValueError, match="continuous"):
        ServingConfig(strategy="ils", backend="real")
    with pytest.raises(ValueError, match="kv_layout"):
        ServingConfig(kv_layout="sparse")
    with pytest.raises(ValueError, match="coverage"):
        ServingConfig(coverage=1.5)
    with pytest.raises(ValueError, match="worker"):
        ServingConfig(workers=0)
    # valid combinations construct fine
    ServingConfig(strategy="scls-pred", predictor="proxy")
    ServingConfig(strategy="oracle", predictor="perfect")
    ServingConfig(strategy="scls-cb", kv_layout="paged")


def test_serving_config_from_dict_and_cli_roundtrip():
    cfg = ServingConfig.from_dict({"strategy": "lb", "workers": 3})
    assert cfg.strategy == "lb" and cfg.workers == 3
    with pytest.raises(ValueError, match="unknown ServingConfig keys"):
        ServingConfig.from_dict({"stratgy": "lb"})
    cli = ServingConfig.from_cli(
        ["--strategy", "scls-pred", "--predictor", "histogram",
         "--kv-layout", "paged", "--workers", "5"], gamma=0.25)
    assert (cli.strategy, cli.predictor, cli.kv_layout) == \
        ("scls-pred", "histogram", "paged")
    assert cli.workers == 5 and cli.gamma == 0.25
    assert ServingConfig.from_dict(cli.to_dict()) == cli
    with pytest.raises(SystemExit):  # invalid combo -> argparse error
        ServingConfig.from_cli(["--strategy", "scls", "--predictor", "proxy"])


def test_strategy_config_and_memory_builders():
    cfg = ServingConfig(strategy="scls-cb", kv_layout="paged", page_tokens=8,
                        slice_len=32)
    s = cfg.strategy_config()
    assert s.name == "SCLS-CB" and s.kv_layout == "paged"
    mem = cfg.memory_estimator(delta_bytes=100.0)
    assert isinstance(mem, PagedMemoryEstimator)
    assert mem.page_tokens == 8
    dense = ServingConfig().memory_estimator(delta_bytes=100.0)
    assert isinstance(dense, AnalyticMemoryEstimator)


def test_serving_config_packing_validation_and_cli():
    """packing='envelope' (PR 10) is opt-in, paged-only, CLI-reachable."""
    assert ServingConfig().packing == "batch-max"
    assert ServingConfig().strategy_config().packing == "batch-max"
    with pytest.raises(ValueError, match="packing"):
        ServingConfig(packing="exact")
    with pytest.raises(ValueError, match="paged"):
        ServingConfig(packing="envelope")  # dense layout: no block pool
    cfg = ServingConfig(strategy="scls-cb", kv_layout="paged",
                        packing="envelope")
    assert cfg.strategy_config().packing == "envelope"
    cli = ServingConfig.from_cli(["--packing", "envelope",
                                  "--kv-layout", "paged"])
    assert cli.packing == "envelope"
    with pytest.raises(SystemExit):  # invalid combo -> argparse error
        ServingConfig.from_cli(["--packing", "envelope"])


def test_envelope_packing_sim_end_to_end(sim_env):
    """A paged sim run under packing='envelope' completes the same request
    set as batch-max (correctness is packing-invariant; only grouping may
    differ) — and SchedulerCore refuses envelope without a block pool."""
    true_lat, est, _ = sim_env
    trace = generate_trace(8.0, 20.0, CODEFUSE, seed=5)
    done = {}
    for packing in ("batch-max", "envelope"):
        cfg = ServingConfig(strategy="scls-cb", kv_layout="paged",
                            workers=2, packing=packing)
        server = cfg.build_sim(true_lat, est)
        assert isinstance(server.core.mem, PagedMemoryEstimator)
        server.replay(copy.deepcopy(trace))
        done[packing] = server.drain(20.0).n_completed
        assert done[packing] > 0
    assert done["envelope"] == done["batch-max"]

    # construction guard: envelope needs the paged pool's block accounting
    dense_env = default_sim_environment("hf")
    with pytest.raises(ValueError, match="PagedMemoryEstimator"):
        SchedulerCore(make_strategy("scls", kv_layout="paged",
                                    packing="envelope"),
                      SimBackend(dense_env[0]), 2, dense_env[1], dense_env[2])


def test_continuous_strategy_rejected_on_noncontinuous_backend(sim_env):
    true_lat, est, mem = sim_env

    class CentralOnly(SimBackend):
        supports_continuous = False

    with pytest.raises(ValueError, match="continuous"):
        SchedulerCore(make_strategy("ils"), CentralOnly(true_lat), 2, est, mem)


# ---------------------------------------------------------------------------
# metrics satellite: TTFT + latency percentiles
# ---------------------------------------------------------------------------
def test_compute_metrics_ttft_and_percentiles():
    reqs = []
    for i in range(100):
        r = Request(rid=i, arrival=0.0, input_len=8, gen_len=10)
        r.done = True
        r.finish_time = float(i + 1)    # latencies 1..100
        r.first_token_time = 0.25 * (i + 1)
        reqs.append(r)
    m = compute_metrics("x", reqs, 100.0, [100.0], [1], 0, 100)
    assert m.p50_response == pytest.approx(np.percentile(np.arange(1, 101), 50))
    assert m.p99_response == pytest.approx(np.percentile(np.arange(1, 101), 99))
    assert m.p50_response < m.p95_response < m.p99_response
    assert m.ttft_mean == pytest.approx(0.25 * np.mean(np.arange(1, 101)))
    assert m.ttft_p95 == pytest.approx(
        0.25 * np.percentile(np.arange(1, 101), 95))


# ---------------------------------------------------------------------------
# real backend (reduced model, every FLOP real)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def real_env():
    import jax
    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.models.registry import get_model
    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2, repeats=1)
    return arch, model, params, est


def _make_engines(model, params, n=2):
    from repro.engine.static_engine import StaticEngine
    return [StaticEngine(model, params, eos_id=1, len_bucket=8)
            for _ in range(n)]


def _in_flight(core, rid):
    return any(kind == "batch_done"
               and any(r.rid == rid for r in payload[1].requests)
               for _, _, kind, payload in core._events)


def test_real_backend_cancel_mid_slice_frees_pages_and_trains_predictor(real_env):
    """Satellite acceptance: cancelling mid-slice on the REAL backend leaks
    no pages (every allocator's free-block count returns to baseline) and
    the prediction pipeline records the truncated length."""
    arch, model, params, est = real_env
    scfg = ServingConfig(strategy="scls-pred", predictor="histogram",
                         backend="real", kv_layout="paged", page_tokens=16,
                         slice_len=8, max_gen=24, gamma=0.25,
                         m_available=64e6, mem_bucket=8)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    server = scfg.build_real(_make_engines(model, params), est, mem)
    allocators = server.core.backend.allocators
    baseline = [a.free_blocks for a in allocators]
    rng = np.random.default_rng(0)

    def prompt(n):
        return rng.integers(0, arch.vocab_size, size=n).astype(np.int32)

    victim = server.submit(prompt(16), gen_len=20, max_gen=24, arrival=0.0)
    others = [server.submit(prompt(8 + i), gen_len=4 + i, max_gen=24,
                            arrival=0.1 * i) for i in range(4)]
    while not victim.finished and not _in_flight(server.core, victim.rid):
        server.step()
    assert not victim.finished, "victim completed before cancellation"
    # mid-slice: its (L_i + S) envelope is reserved right now
    assert any(a.used_blocks > 0 for a in allocators)
    assert victim.cancel()
    m = server.drain()
    assert victim.cancelled and not victim.done
    assert victim.request.generated < 20
    assert all(h.done for h in others)
    assert m.n_completed == 4
    # no page leaks: every worker's free list is back to baseline
    assert [a.free_blocks for a in allocators] == baseline
    assert all(not a.owners() for a in allocators)
    # online feedback observed all 5 terminal requests incl. the truncation
    assert server.core.predictor.n_observed == 5


def test_real_backend_streaming_token_parity(real_env):
    """Tokens streamed per slice through SliceServer equal direct one-shot
    generation (greedy determinism survives the online path)."""
    arch, model, params, est = real_env
    scfg = ServingConfig(strategy="scls", backend="real", slice_len=8,
                         max_gen=24, gamma=0.25, m_available=64e6,
                         mem_bucket=8)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    engines = _make_engines(model, params)
    server = scfg.build_real(engines, est, mem)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, arch.vocab_size, size=n).astype(np.int32)
               for n in (12, 20, 7)]
    gens = (14, 9, 21)
    handles = [server.submit(p, gen_len=g, max_gen=24, arrival=0.2 * i)
               for i, (p, g) in enumerate(zip(prompts, gens))]
    streamed = [list(h.tokens()) for h in handles]
    server.drain()
    for h, p, g, got in zip(handles, prompts, gens, streamed):
        assert h.done and h.request.n_schedules >= 2  # sliced, not one-shot
        want = engines[0].serve_batch([p], slice_len=32,
                                      forced_gen_lens=[g]).results[0]["tokens"]
        assert got == want
        assert h.request.output_tokens == want


def test_real_backend_eos_driven_submission(real_env):
    """gen_len=None decodes until the model's own EOS (or max_gen)."""
    arch, model, params, est = real_env
    scfg = ServingConfig(strategy="scls", backend="real", slice_len=4,
                         max_gen=6, gamma=0.25, m_available=64e6,
                         mem_bucket=8)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    server = scfg.build_real(_make_engines(model, params, n=1), est, mem)
    rng = np.random.default_rng(2)
    p = rng.integers(0, arch.vocab_size, size=10).astype(np.int32)
    h = server.submit(p, gen_len=None, max_gen=6)
    req = h.result()
    assert h.done
    assert 1 <= req.generated <= 6
    toks = req.output_tokens
    if 1 in toks:  # model emitted its EOS: stream ends right there
        assert toks.index(1) == len(toks) - 1
    else:          # never EOS'd: capped by max_gen
        assert req.generated == 6


def test_static_engine_per_row_eos_sentinel(real_env):
    """A forced length >= the sentinel makes that row EOS-driven while
    forced rows in the same batch keep exact emulated lengths."""
    arch, model, params, est = real_env
    eng = _make_engines(model, params, n=1)[0]
    rng = np.random.default_rng(3)
    p0 = rng.integers(0, arch.vocab_size, size=9).astype(np.int32)
    p1 = rng.integers(0, arch.vocab_size, size=13).astype(np.int32)
    res = eng.serve_batch([p0, p1], slice_len=6, forced_gen_lens=[3, 1 << 30])
    r0, r1 = res.results
    assert r0["n_valid"] == 3
    toks = r1["tokens"]
    if 1 in toks:
        assert toks.index(1) == len(toks) - 1 and r1["finished"]
    else:
        assert r1["n_valid"] == res.steps
