"""Shared test fixtures/helpers."""
import pytest


def optional_hypothesis():
    """Import hypothesis, degrading gracefully when absent: property tests
    skip but the rest of the module still collects and runs.

    Usage::

        from conftest import optional_hypothesis
        given, settings, st = optional_hypothesis()
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def _skip(*_a, **_k):
            return pytest.mark.skip(reason="hypothesis not installed "
                                           "(see requirements.txt)")

        class _StrategyStub:
            def __getattr__(self, _name):
                return lambda *a, **k: None

        return _skip, _skip, _StrategyStub()
