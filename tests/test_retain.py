"""Persistent paged KV storage (kv_retain="request"): prefix pages survive
across slices, re-prefill becomes a page-table remap — token-exactness vs
the dense §3.3 re-prefill path, page-lifetime invariants (finish / cancel
/ evict all return the pool to baseline), and the reprefill_tokens metric.
"""
import itertools

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kvcache import PageAllocator
from repro.serving import ServingConfig


@pytest.fixture(scope="module")
def real_env():
    import jax
    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.models.registry import get_model
    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2, repeats=1)
    return arch, model, params, est


def _paged_engine(model, params, pool_tokens=512, page_tokens=8):
    from repro.engine.static_engine import StaticEngine
    return StaticEngine(model, params, eos_id=1, len_bucket=8,
                        kv_layout="paged", page_tokens=page_tokens,
                        kv_pool_tokens=pool_tokens)


def _dense_engine(model, params):
    from repro.engine.static_engine import StaticEngine
    return StaticEngine(model, params, eos_id=1, len_bucket=8)


def _prompts(arch, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, arch.vocab_size, size=s).astype(np.int32)
            for s in sizes]


# ---------------------------------------------------------------------------
# engine level: the tentpole correctness property
# ---------------------------------------------------------------------------
def test_persistent_paged_token_exact_across_slices(real_env):
    """Serving in >= 3 slices with retained pages (zero re-prefill) yields
    exactly the dense path's tokens (which re-prefills every slice)."""
    arch, model, params, est = real_env
    prompts = _prompts(arch, [7, 12, 4], seed=0)
    totals = [20, 9, 16]  # 20 tokens at slice 8 -> 3 slices
    dense = _dense_engine(model, params)
    paged = _paged_engine(model, params)

    def run(engine, paged_mode):
        outs = [[] for _ in prompts]
        n_slices = 0
        reprefill = 0
        while any(len(o) < t for o, t in zip(outs, totals)):
            idx = [i for i in range(len(prompts)) if len(outs[i]) < totals[i]]
            kw = dict(forced_gen_lens=[totals[i] - len(outs[i]) for i in idx],
                      already_generated=[outs[i] for i in idx])
            if paged_mode:
                res = engine.serve_batch_paged(
                    [prompts[i] for i in idx], 8, [100 + i for i in idx], **kw)
            else:
                res = engine.serve_batch([prompts[i] for i in idx], 8, **kw)
            reprefill += res.reprefill_tokens
            n_slices += 1
            for s, i in enumerate(idx):
                outs[i].extend(res.results[s]["tokens"])
        return outs, n_slices, reprefill

    want, k_dense, rep_dense = run(dense, False)
    got, k_paged, rep_paged = run(paged, True)
    assert k_dense >= 3 and k_paged >= 3
    assert got == want
    assert rep_paged == 0        # resumed slices remap pages, no prefill
    assert rep_dense > 0         # the dense path pays §3.3 every slice
    for i in range(len(prompts)):
        paged.release_request(100 + i)
    assert paged.allocator.free_blocks == paged.allocator.n_pages


def test_persistent_paged_eos_rows_match_dense(real_env):
    """EOS-driven rows (forced >= sentinel) behave identically on the
    persistent path, including mid-batch early completion."""
    from repro.engine.static_engine import EOS_DRIVEN
    arch, model, params, est = real_env
    prompts = _prompts(arch, [9, 13], seed=7)
    dense = _dense_engine(model, params)
    paged = _paged_engine(model, params)
    rd = dense.serve_batch(prompts, 6, forced_gen_lens=[3, EOS_DRIVEN])
    rp = paged.serve_batch_paged(prompts, 6, [1, 2],
                                 forced_gen_lens=[3, EOS_DRIVEN])
    for a, b in zip(rd.results, rp.results):
        assert a["tokens"] == b["tokens"]
        assert a["n_valid"] == b["n_valid"]
        assert a["finished"] == b["finished"]
    assert rd.steps == rp.steps


def test_evict_on_pressure_falls_back_to_reprefill(real_env):
    """A parked resident is evicted LRU when the pool runs dry; its next
    slice re-prefills classically (counted) and stays token-exact."""
    arch, model, params, est = real_env
    p1, p2 = _prompts(arch, [10, 9], seed=1)
    # 5 pages x 8 tokens: each request needs 3 pages -> the second dispatch
    # must evict the parked first
    eng = _paged_engine(model, params, pool_tokens=40, page_tokens=8)
    dense = _dense_engine(model, params)
    o1 = list(eng.serve_batch_paged([p1], 8, [1],
                                    forced_gen_lens=[16]).results[0]["tokens"])
    o2 = list(eng.serve_batch_paged([p2], 8, [2],
                                    forced_gen_lens=[16]).results[0]["tokens"])
    assert eng.n_evictions == 1
    res = eng.serve_batch_paged([p1], 8, [1], forced_gen_lens=[8],
                                already_generated=[o1])
    assert res.reprefill_tokens == len(p1) + len(o1)  # classic §3.3 cost
    o1 += res.results[0]["tokens"]
    res = eng.serve_batch_paged([p2], 8, [2], forced_gen_lens=[8],
                                already_generated=[o2])
    o2 += res.results[0]["tokens"]
    assert o1 == dense.serve_batch([p1], 32,
                                   forced_gen_lens=[16]).results[0]["tokens"]
    assert o2 == dense.serve_batch([p2], 32,
                                   forced_gen_lens=[16]).results[0]["tokens"]
    eng.release_request(1)
    eng.release_request(2)
    assert eng.allocator.free_blocks == eng.allocator.n_pages


# ---------------------------------------------------------------------------
# serving stack: kv_retain="request" end to end
# ---------------------------------------------------------------------------
def _retain_server(model, params, est, kv_retain, workers=1, max_gen=32,
                   slice_len=8, page_tokens=16, m_available=64e6):
    from repro.engine.static_engine import StaticEngine
    scfg = ServingConfig(strategy="scls", backend="real", kv_layout="paged",
                         page_tokens=page_tokens, kv_retain=kv_retain,
                         slice_len=slice_len, max_gen=max_gen, gamma=0.25,
                         m_available=m_available, mem_bucket=8,
                         workers=workers)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    if kv_retain == "request":
        engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                                kv_layout="paged", page_tokens=page_tokens,
                                kv_pool_tokens=mem.total_blocks * page_tokens)
                   for _ in range(workers)]
    else:
        engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
                   for _ in range(workers)]
    return scfg.build_real(engines, est, mem)


def test_retain_request_zero_reprefill_token_exact(real_env):
    """Acceptance: with kv_retain="request", uninterrupted requests resume
    with ZERO re-prefill while streams stay token-exact vs the dense
    contiguous path, across >= 3 slices."""
    arch, model, params, est = real_env
    prompts = _prompts(arch, [12, 20, 7], seed=2)
    gens = (20, 9, 26)
    streams = {}
    for retain in ("slice", "request"):
        server = _retain_server(model, params, est, retain)
        baseline = [a.free_blocks for a in server.core.backend.allocators]
        hs = [server.submit(p, gen_len=g, max_gen=32, arrival=0.2 * i)
              for i, (p, g) in enumerate(zip(prompts, gens))]
        m = server.drain()
        assert all(h.done for h in hs)
        assert max(h.request.n_schedules for h in hs) >= 3
        streams[retain] = [h.request.output_tokens for h in hs]
        # pool back to baseline after every request finished
        assert [a.free_blocks
                for a in server.core.backend.allocators] == baseline
        if retain == "request":
            assert m.reprefill_tokens == 0
            assert server.core.mem.retained_blocks == 0
        else:
            assert m.reprefill_tokens > 0
    assert streams["slice"] == streams["request"]


def test_retain_request_cancel_mid_flight_returns_pool_to_baseline(real_env):
    """Cancelling mid-flight releases the retained prefix pages at the
    slice boundary — allocator free-block count returns to baseline."""
    arch, model, params, est = real_env
    server = _retain_server(model, params, est, "request")
    allocators = server.core.backend.allocators
    baseline = [a.free_blocks for a in allocators]
    victim = server.submit(_prompts(arch, [16], seed=3)[0], gen_len=24,
                           max_gen=32, arrival=0.0)
    others = [server.submit(p, gen_len=6 + i, max_gen=32, arrival=0.1 * i)
              for i, p in enumerate(_prompts(arch, [8, 9], seed=4))]
    while not victim.finished and victim.request.generated == 0:
        server.step()
    assert not victim.finished, "victim finished before cancellation"
    # mid-flight: its prefix pages are retained right now
    assert any(a.used_blocks > 0 for a in allocators)
    assert victim.cancel()
    m = server.drain()
    assert victim.cancelled and not victim.done
    assert all(h.done for h in others)
    assert m.n_completed == 2
    assert [a.free_blocks for a in allocators] == baseline
    assert all(not a.owners() for a in allocators)
    assert server.core.mem.retained_blocks == 0


def test_retain_request_eos_finish_releases_pages(real_env):
    """An EOS-driven request (gen_len=None) releases its retained pages
    when the model's own EOS ends it."""
    arch, model, params, est = real_env
    server = _retain_server(model, params, est, "request", slice_len=4,
                            max_gen=6)
    allocators = server.core.backend.allocators
    baseline = [a.free_blocks for a in allocators]
    p = _prompts(arch, [10], seed=5)[0]
    h = server.submit(p, gen_len=None, max_gen=6)
    req = h.result()
    server.drain()
    assert h.done and 1 <= req.generated <= 6
    assert [a.free_blocks for a in allocators] == baseline
    assert all(not a.owners() for a in allocators)


def test_retain_request_streaming_matches_one_shot(real_env):
    """Per-slice streamed tokens through the handle equal direct one-shot
    generation (greedy determinism survives page persistence)."""
    arch, model, params, est = real_env
    server = _retain_server(model, params, est, "request")
    ref_engine = _dense_engine(model, params)
    p = _prompts(arch, [14], seed=6)[0]
    h = server.submit(p, gen_len=18, max_gen=32)
    got = list(itertools.islice(h.tokens(), 18))
    server.drain()
    assert h.request.n_schedules >= 3
    want = ref_engine.serve_batch([p], slice_len=32,
                                  forced_gen_lens=[18]).results[0]["tokens"]
    assert got == want


def test_unsatisfiable_batch_unwinds_partial_reservations(real_env):
    """Review regression: when a batch cannot fit even after evicting every
    parked resident, the rows already granted in that call are unwound —
    the pool is not wedged and the same rids can be served individually."""
    arch, model, params, est = real_env
    p1, p2 = _prompts(arch, [10, 10], seed=8)
    # 4 pages x 8 tokens: one request needs 3 pages (10 + 8 -> 18 tokens),
    # two together need 6 — nothing parked to evict, so the dispatch of
    # [p1, p2] must fail cleanly
    eng = _paged_engine(model, params, pool_tokens=32, page_tokens=8)
    with pytest.raises(MemoryError):
        eng.serve_batch_paged([p1, p2], 8, [1, 2], forced_gen_lens=[4, 4])
    assert eng.allocator.free_blocks == eng.allocator.n_pages  # unwound
    assert not eng.allocator.owners()
    # the pool is usable and rid 1 is servable (no KeyError on re-reserve)
    res = eng.serve_batch_paged([p1], 8, [1], forced_gen_lens=[4])
    assert res.results[0]["n_valid"] == 4
    eng.release_request(1)
    assert eng.allocator.free_blocks == eng.allocator.n_pages


# ---------------------------------------------------------------------------
# allocator churn property (satellite)
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.sampled_from("RESK"),
                          st.integers(1, 60)), min_size=1, max_size=40),
       st.sampled_from([4, 8, 16]))
def test_reserve_retain_release_churn_never_double_charges(ops, page_tokens):
    """Property: any interleaving of reserve / extend / shrink / release
    keeps the pool exactly charged — pages handed out are unique and
    non-null, used + free always equals the pool size, and releasing all
    owners restores the free list completely."""
    a = PageAllocator(n_pages=12, page_tokens=page_tokens)
    held = {}
    for owner, op, n_tokens in ops:
        try:
            if op == "R":
                held[owner] = a.reserve(owner, n_tokens)
            elif op == "E":
                held[owner].extend(a.extend(owner, n_tokens))
            elif op == "S":
                freed = a.shrink(owner, n_tokens)
                if freed:
                    del held[owner][-freed:]
            elif op == "K":
                a.release(owner)
                del held[owner]
        except (KeyError, MemoryError):
            pass  # rejected ops must leave the pool untouched (checked below)
        handed = [p for pages in held.values() for p in pages]
        assert len(handed) == len(set(handed)), "page handed to two owners"
        assert PageAllocator.NULL_PAGE not in handed
        assert a.used_blocks == len(handed)
        assert a.used_blocks + a.free_blocks == 12
        for owner, pages in held.items():
            assert a.pages_of(owner) == pages
    for owner in list(held):
        a.release(owner)
    assert a.free_blocks == 12


# ---------------------------------------------------------------------------
# ServingConfig validation (satellite regression)
# ---------------------------------------------------------------------------
def test_page_tokens_validation_rejects_bad_values():
    with pytest.raises(ValueError, match="page_tokens"):
        ServingConfig(kv_layout="paged", page_tokens=0)
    with pytest.raises(ValueError, match="integer"):
        ServingConfig(kv_layout="paged", page_tokens=16.0)
    with pytest.raises(ValueError, match="integer"):
        ServingConfig(kv_layout="paged", page_tokens=True)
    # a block size that yields a zero-block pool is named at config time
    # instead of failing with an opaque allocator/shape error downstream
    cfg = ServingConfig(strategy="scls", backend="real", kv_layout="paged",
                        page_tokens=4096, m_available=1e3)
    with pytest.raises(ValueError, match="zero-block"):
        cfg.memory_estimator(delta_bytes=1.0)


def test_kv_retain_validation():
    with pytest.raises(ValueError, match="kv_retain"):
        ServingConfig(kv_retain="forever")
    with pytest.raises(ValueError, match="paged"):
        ServingConfig(backend="real", kv_retain="request")  # dense layout
    with pytest.raises(ValueError, match="sim"):
        ServingConfig(backend="sim", kv_layout="paged", kv_retain="request")
    cfg = ServingConfig(backend="real", kv_layout="paged",
                        kv_retain="request")
    assert cfg.kv_retain == "request"
    cli = ServingConfig.from_cli(
        ["--backend", "real", "--kv-layout", "paged",
         "--kv-retain", "request"])
    assert cli.kv_retain == "request"


def test_retain_request_requires_persistent_engines(real_env):
    """RealBackend refuses kv_retain='request' over dense engines — the
    retention contract needs engine-owned page pools."""
    arch, model, params, est = real_env
    scfg = ServingConfig(strategy="scls", backend="real", kv_layout="paged",
                         kv_retain="request", m_available=64e6, mem_bucket=8,
                         workers=1)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    with pytest.raises(TypeError, match="persistent-paged"):
        scfg.build_real([_dense_engine(model, params)], est, mem)


# ---------------------------------------------------------------------------
# fused RoPE+paged-KV kernels (PR 10): engine- and server-level sweeps
# ---------------------------------------------------------------------------
def _fused_engine(model, params, attn_impl, pool_tokens=512, page_tokens=8):
    from repro.engine.static_engine import StaticEngine
    return StaticEngine(model, params, eos_id=1, len_bucket=8,
                        kv_layout="paged", page_tokens=page_tokens,
                        kv_pool_tokens=pool_tokens, attn_impl=attn_impl)


def test_fused_attn_impl_token_exact_vs_unfused(real_env):
    """attn_impl="fused" (single-pass RoPE+write prefill, single-launch
    RoPE+append+attend decode) must generate EXACTLY the unfused path's
    tokens across >= 3 slices — covering the full-prefill, retained-resume
    (tail), and decode kernels."""
    arch, model, params, est = real_env
    prompts = _prompts(arch, [7, 12, 4], seed=0)
    totals = [20, 9, 16]

    def run(impl):
        eng = _fused_engine(model, params, impl)
        outs = [[] for _ in prompts]
        while any(len(o) < t for o, t in zip(outs, totals)):
            idx = [i for i in range(len(prompts)) if len(outs[i]) < totals[i]]
            res = eng.serve_batch_paged(
                [prompts[i] for i in idx], 8, [100 + i for i in idx],
                forced_gen_lens=[totals[i] - len(outs[i]) for i in idx],
                already_generated=[outs[i] for i in idx])
            for s, i in enumerate(idx):
                outs[i].extend(res.results[s]["tokens"])
        return outs

    assert run("fused") == run("unfused")


def test_fused_attn_impl_validated():
    import jax
    from repro.configs import get_config
    from repro.engine.static_engine import StaticEngine
    from repro.models.registry import get_model
    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="attn_impl"):
        StaticEngine(model, params, attn_impl="turbo")


def test_fused_server_level_token_parity(real_env):
    """Server-level sweep: a SliceServer over fused paged engines streams
    exactly the tokens of one over unfused engines (same SCLS schedule,
    same prompts)."""
    arch, model, params, est = real_env
    page_tokens = 8
    scfg = ServingConfig(strategy="scls", backend="real", kv_layout="paged",
                         page_tokens=page_tokens, kv_retain="request",
                         slice_len=8, max_gen=24, gamma=0.25,
                         m_available=64e6, mem_bucket=8, workers=1)
    prompts = _prompts(arch, [12, 9, 5], seed=4)
    gens = (14, 6, 10)
    streams = {}
    for impl in ("unfused", "fused"):
        mem = scfg.memory_estimator(model.kv_bytes_per_token())
        engines = [_fused_engine(model, params, impl,
                                 pool_tokens=mem.total_blocks * page_tokens,
                                 page_tokens=page_tokens)]
        server = scfg.build_real(engines, est, mem)
        handles = [server.submit(p, gen_len=g, max_gen=24, arrival=0.1 * i)
                   for i, (p, g) in enumerate(zip(prompts, gens))]
        server.drain()
        assert all(h.done for h in handles)
        streams[impl] = [h.request.output_tokens for h in handles]
    assert streams["fused"] == streams["unfused"]
