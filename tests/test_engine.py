"""Serving-engine semantics: static batching (paper §2.4), slicing
invariance (SCLS §4), continuous batching (ILS baseline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engine.continuous_engine import ContinuousEngine
from repro.engine.static_engine import StaticEngine
from repro.models.registry import get_model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(KEY)
    return cfg, model, params


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, size=s).astype(np.int32) for s in sizes]


def test_static_batching_invalid_and_pad_tokens(dense_setup):
    """Completed requests keep generating invalid tokens until the batch
    finishes (paper §2.4), and short inputs get pad tokens."""
    cfg, model, params = dense_setup
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    res = eng.serve_batch(_prompts(cfg, [5, 11, 3]), slice_len=8,
                          forced_gen_lens=[3, 8, 20])
    assert res.steps == 8  # ran the full slice: request 2 not finished
    r0, r1, r2 = res.results
    assert r0["n_valid"] == 3 and r0["invalid"] == 5 and r0["finished"]
    assert r1["n_valid"] == 8 and r1["finished"]
    assert r2["n_valid"] == 8 and not r2["finished"]
    assert r0["pad"] == res.batch_input_len - 5
    assert r2["pad"] == res.batch_input_len - 3


def test_static_batching_early_return_when_all_finish(dense_setup):
    cfg, model, params = dense_setup
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    res = eng.serve_batch(_prompts(cfg, [4, 6]), slice_len=32,
                          forced_gen_lens=[2, 3])
    assert res.early_return and res.steps == 3  # stops when ALL are done


def test_slice_invariance_of_generated_tokens(dense_setup):
    """THE SCLS correctness property: serving a request in k slices with
    prefill re-computation yields exactly the tokens of one-shot serving."""
    cfg, model, params = dense_setup
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    prompts = _prompts(cfg, [7])
    total = 20
    one_shot = eng.serve_batch(prompts, slice_len=32,
                               forced_gen_lens=[total]).results[0]["tokens"]
    # now in slices of 8, rescheduling with already_generated
    got, remaining = [], total
    while remaining > 0:
        res = eng.serve_batch(prompts, slice_len=8, forced_gen_lens=[remaining],
                              already_generated=[got])
        got.extend(res.results[0]["tokens"])
        remaining = total - len(got)
    assert got == one_shot


def test_slice_invariance_with_batch_companions(dense_setup):
    """Tokens of a request must not depend on its batch companions."""
    cfg, model, params = dense_setup
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    p = _prompts(cfg, [9, 4, 13], seed=2)
    solo = eng.serve_batch([p[0]], slice_len=8, forced_gen_lens=[8]).results[0]["tokens"]
    together = eng.serve_batch(p, slice_len=8,
                               forced_gen_lens=[8, 5, 6]).results[0]["tokens"]
    assert solo == together


def test_eos_detection_without_forced_lens(dense_setup):
    cfg, model, params = dense_setup
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    res = eng.serve_batch(_prompts(cfg, [5]), slice_len=8)
    r = res.results[0]
    assert 1 <= r["n_valid"] <= 8
    if r["n_valid"] < 8:
        assert r["tokens"][-1] == 1  # ended on a real EOS


def test_continuous_engine_matches_static_tokens(dense_setup):
    cfg, model, params = dense_setup
    ce = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8)
    se = StaticEngine(model, params, eos_id=1, len_bucket=8)
    prompts = _prompts(cfg, [5, 9, 4], seed=3)
    res = ce.serve(prompts, forced_gen_lens=[4, 6, 3])
    for i, p in enumerate(prompts):
        want = se.serve_batch([p], slice_len=16,
                              forced_gen_lens=[[4, 6, 3][i]]).results[0]["tokens"]
        assert res.outputs[i] == want


def test_continuous_engine_respects_slot_cap(dense_setup):
    cfg, model, params = dense_setup
    ce = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8)
    res = ce.serve(_prompts(cfg, [4] * 5, seed=4), forced_gen_lens=[3] * 5)
    # with 2 slots and 5 requests of 3 tokens each: at least 3 join waves
    assert res.join_order == [0, 1, 2, 3, 4]
    assert all(len(o) == 3 for o in res.outputs)


def test_paged_engine_token_exact_vs_dense(dense_setup):
    """kv_layout="paged" is pure layout: identical greedy tokens, join
    order, and iteration count vs. the dense engine on the same seeds."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [5, 9, 4, 12, 3], seed=3)
    forced = [4, 6, 3, 5, 7]
    de = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8)
    pe = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8, kv_layout="paged",
                          page_tokens=8)
    rd = de.serve(prompts, forced_gen_lens=forced)
    rp = pe.serve(prompts, forced_gen_lens=forced)
    assert rp.outputs == rd.outputs
    assert rp.join_order == rd.join_order
    assert rp.iterations == rd.iterations


def test_paged_engine_token_exact_with_eos(dense_setup):
    """Exactness must also hold when EOS (not forced lengths) ends rows."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [6, 11, 4], seed=7)
    de = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8)
    pe = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8, kv_layout="paged",
                          page_tokens=8)
    rd = de.serve(prompts, max_gen=12)
    rp = pe.serve(prompts, max_gen=12)
    assert rp.outputs == rd.outputs


def test_paged_engine_parallelism_bounded_by_free_pages(dense_setup):
    """Under one shared KV-token budget the paged engine packs short
    requests into strictly more parallel rows than dense worst-case slots
    (the tentpole claim), while pages are reserved at join and all freed
    by the time serving drains."""
    cfg, model, params = dense_setup
    prompts = _prompts(cfg, [4] * 6, seed=4)
    forced = [3] * 6
    W, budget = 64, 2 * 64
    de = ContinuousEngine(model, params, max_slots=budget // W,
                          max_context=W, eos_id=1, len_bucket=8)
    pe = ContinuousEngine(model, params, max_slots=6, max_context=W,
                          eos_id=1, len_bucket=8, kv_layout="paged",
                          page_tokens=8, total_kv_tokens=budget)
    rd = de.serve(prompts, forced_gen_lens=forced)
    rp = pe.serve(prompts, forced_gen_lens=forced)
    assert rp.outputs == rd.outputs
    # each request's envelope = 8 (bucketed prompt) + 3 -> 2 pages of 8;
    # 16 pages in the pool -> all 6 requests fit at once vs 2 dense slots
    assert rd.peak_parallel == 2
    assert rp.peak_parallel > rd.peak_parallel
    assert rp.iterations < rd.iterations
    assert pe.alloc.free_blocks == pe.alloc.n_pages  # everything released


def test_paged_engine_rejects_bad_geometry(dense_setup):
    cfg, model, params = dense_setup
    with pytest.raises(ValueError):
        ContinuousEngine(model, params, max_context=60, kv_layout="paged",
                         page_tokens=16)  # 60 % 16 != 0


def test_paged_engine_raises_on_never_fitting_request(dense_setup):
    """A request whose envelope exceeds the whole page pool must raise —
    waiting forever would silently drop it (and everything FCFS behind).
    The raise happens BEFORE any reservation, so no pages leak and the
    engine stays usable."""
    cfg, model, params = dense_setup
    eng = ContinuousEngine(model, params, max_slots=2, max_context=64,
                           eos_id=1, len_bucket=8, kv_layout="paged",
                           page_tokens=8, total_kv_tokens=32)
    small = _prompts(cfg, [4], seed=5)[0]
    huge = _prompts(cfg, [28], seed=5)[0]
    with pytest.raises(ValueError, match="exceeds the page pool"):
        eng.serve([small, huge], forced_gen_lens=[3, 20])
    assert eng.alloc.free_blocks == eng.alloc.n_pages  # nothing leaked
    res = eng.serve([small], forced_gen_lens=[3])  # engine still works
    assert len(res.outputs[0]) == 3


def test_engine_profiler_produces_fittable_samples(dense_setup):
    from repro.engine.profiler import fit_estimator
    cfg, model, params = dense_setup
    est, prmse, drmse = fit_estimator(model, params, batch_sizes=(1, 2),
                                      input_lens=(16, 32), n_decode_iters=2,
                                      repeats=1)
    assert est.t_serve(2, 32, 4) > 0
    assert np.isfinite(prmse) and np.isfinite(drmse)


def test_paged_engine_releases_pages_when_serve_stops_mid_flight(dense_setup):
    """A serve() that ends with rows still in flight (max_iters exhaustion
    here, standing in for a mid-iteration exception) must return every
    in-flight envelope to the pool: the allocator outlives serve(), so a
    stranded owner would wedge every later call — the cancel-leak class
    the allocator-pairing lint flags."""
    cfg, model, params = dense_setup
    pe = ContinuousEngine(model, params, max_slots=2, max_context=64,
                          eos_id=1, len_bucket=8, kv_layout="paged",
                          page_tokens=8)
    prompts = _prompts(cfg, [5, 9], seed=11)
    res = pe.serve(prompts, forced_gen_lens=[30, 30], max_iters=3)
    assert res.iterations == 3  # stopped with both rows unfinished
    assert all(len(o) < 30 for o in res.outputs)
    assert pe.alloc.free_blocks == pe.alloc.n_pages  # nothing stranded
    # and the pool is genuinely reusable: a full serve() still works
    res2 = pe.serve(prompts, forced_gen_lens=[3, 3])
    assert all(len(o) == 3 for o in res2.outputs)
    assert pe.alloc.free_blocks == pe.alloc.n_pages
