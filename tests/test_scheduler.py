"""Unit + property tests for the paper's core scheduling algorithms."""
import itertools

import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.batcher import batch_fits, dp_batch, fcfs_batch
from repro.core.estimator import (LatencyCoeffs, ServingTimeEstimator,
                                  a100_llama13b_hf_profile,
                                  a100_llama13b_profile, fit_bilinear)
from repro.core.interval import next_interval
from repro.core.memory import (AnalyticMemoryEstimator,
                               PagedMemoryEstimator,
                               RuleBasedMemoryEstimator, model_kv_delta)
from repro.core.offloader import MaxMinOffloader, RoundRobinOffloader
from repro.core.request import Batch, Request, bucket_len
from repro.core.schedulers import ALL_STRATEGIES, make_strategy


def _requests(lens, arrival=0.0):
    return [Request(rid=i, arrival=arrival, input_len=int(l), gen_len=10)
            for i, l in enumerate(lens)]


def _est(p=(1e-4, 1e-3, 1e-4, 1e-2), d=(1e-6, 1e-4, 1e-6, 1e-3), bucket=1):
    return ServingTimeEstimator(LatencyCoeffs(*p), LatencyCoeffs(*d), bucket=bucket)


# ---------------------------------------------------------------------------
# estimator (Eq. 1-4)
# ---------------------------------------------------------------------------
def test_decode_sum_closed_form_matches_explicit_sum():
    est = _est()
    for N in (1, 3, 17):
        for L in (1, 100, 1000):
            for S in (1, 8, 128):
                explicit = sum(est.tau_decode(L + l, N) for l in range(1, S + 1))
                assert est.t_decode_sum(N, L, S) == pytest.approx(explicit, rel=1e-9)


def test_fit_bilinear_recovers_exact_coefficients():
    true = LatencyCoeffs(3e-5, 2e-3, 1e-4, 5e-2)
    samples = [(N, L, true(N, L)) for N in (1, 2, 4, 8) for L in (16, 64, 256)]
    fit, rmse = fit_bilinear(samples)
    assert rmse < 1e-12
    np.testing.assert_allclose(fit.as_array(), true.as_array(), rtol=1e-6)


def test_estimator_fit_end_to_end():
    true = a100_llama13b_profile()
    pre = [(N, L, true.t_prefill(N, L)) for N in (1, 4, 16) for L in (32, 256, 1024)]
    dec = [(N, L, true.tau_decode(L, N)) for N in (1, 4, 16) for L in (32, 256, 1024)]
    est, prmse, drmse = ServingTimeEstimator.fit(pre, dec)
    assert prmse < 1e-9 and drmse < 1e-9
    assert est.t_serve(8, 512, 128) == pytest.approx(true.t_serve(8, 512, 128), rel=1e-6)


def test_bucketing_rounds_up():
    assert bucket_len(1, 128) == 128
    assert bucket_len(128, 128) == 128
    assert bucket_len(129, 128) == 256
    assert bucket_len(77, 1) == 77


# ---------------------------------------------------------------------------
# memory estimator (Eq. 5-9 + Algorithm 2)
# ---------------------------------------------------------------------------
def test_analytic_memory_eq5_and_eq8():
    mem = AnalyticMemoryEstimator(delta_bytes=1000.0, m_available=1e6, zeta=1.0)
    # Eq. 5: (L+S)*N*delta
    assert mem.kv_bytes(4, 100, 28) == (100 + 28) * 4 * 1000.0
    # Eq. 8 closed form == bisection on fits()
    for L in (10, 100, 500):
        nmax = mem.max_batch_size(L, 28)
        assert mem.fits(nmax, L, 28)
        assert not mem.fits(nmax + 1, L, 28)


def test_zeta_shrinks_capacity():
    m1 = AnalyticMemoryEstimator(1000.0, 1e6, zeta=1.0)
    m2 = AnalyticMemoryEstimator(1000.0, 1e6, zeta=0.5)
    assert m2.max_batch_size(100, 28) <= m1.max_batch_size(100, 28) / 2 + 1


def test_rule_based_matches_paper_algorithm2():
    mem = RuleBasedMemoryEstimator()
    # paper: L>1024 -> N<=12; L>512 -> N<=22; else N<=28 (L = L_i + S)
    assert mem.fits(12, 1000, 128) and not mem.fits(13, 1000, 128)
    assert mem.fits(22, 500, 128) and not mem.fits(23, 500, 128)
    assert mem.fits(28, 100, 128) and not mem.fits(29, 100, 128)


def test_kv_delta_mesh_aware():
    # sharding KV heads over 8 model shards divides delta by 8
    assert model_kv_delta(40, 40, 128, 2, 8) == model_kv_delta(40, 40, 128, 2) / 8
    # MQA (1 kv head) cannot shard: delta unchanged
    assert model_kv_delta(10, 1, 128, 2, 8) == model_kv_delta(10, 1, 128, 2)


# ---------------------------------------------------------------------------
# DP batcher (Algorithm 1) — optimality via brute force + hypothesis
# ---------------------------------------------------------------------------
def _brute_force_best(lens, S, est, mem, cap=None):
    """Optimal contiguous partition of the sorted requests."""
    lens = sorted(lens)
    n = len(lens)
    best = float("inf")
    for cuts in itertools.product([0, 1], repeat=n - 1):
        groups, cur = [], [lens[0]]
        for i, c in enumerate(cuts):
            if c:
                groups.append(cur)
                cur = []
            cur.append(lens[i + 1])
        groups.append(cur)
        total, ok = 0.0, True
        for g in groups:
            N, L = len(g), max(g)
            if cap is not None and N > cap:
                ok = False
                break
            if not mem.fits(N, L, S):
                ok = False
                break
            total += est.t_serve(N, L, S)
        if ok:
            best = min(best, total)
    return best


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 512), min_size=1, max_size=8),
       st.sampled_from([8, 64, 128]))
def test_dp_batcher_is_optimal(lens, S):
    est = _est()
    mem = AnalyticMemoryEstimator(delta_bytes=100.0, m_available=3e5, zeta=1.0)
    batches = dp_batch(_requests(lens), S, est, mem)
    got = sum(b.est_time for b in batches)
    want = _brute_force_best(lens, S, est, mem)
    assert got == pytest.approx(want, rel=1e-9)
    # every batch respects memory
    for b in batches:
        assert mem.fits(b.size, b.input_len, S)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(1, 512), min_size=1, max_size=10))
def test_dp_batcher_partitions_exactly(lens):
    est = _est()
    mem = AnalyticMemoryEstimator(delta_bytes=100.0, m_available=5e5)
    reqs = _requests(lens)
    batches = dp_batch(reqs, 64, est, mem)
    seen = sorted(r.rid for b in batches for r in b.requests)
    assert seen == sorted(r.rid for r in reqs)
    # contiguity in sorted order: batch input length = max member length
    for b in batches:
        assert b.input_len == max(r.effective_input_len for r in b.requests)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 256), min_size=1, max_size=8),
       st.integers(1, 4))
def test_dp_with_cap_respects_cap_and_optimality(lens, cap):
    est = _est()
    mem = AnalyticMemoryEstimator(delta_bytes=10.0, m_available=1e6)
    batches = dp_batch(_requests(lens), 32, est, mem, max_batch_size=cap)
    assert all(b.size <= cap for b in batches)
    want = _brute_force_best(lens, 32, est, mem, cap=cap)
    got = sum(b.est_time for b in batches)
    assert got == pytest.approx(want, rel=1e-9)


def test_separate_batching_beats_padding_together():
    """Paper Fig. 11: 15 short + 1 long is better served as two batches
    (measured with HF-transformers in the paper)."""
    est = a100_llama13b_hf_profile()
    mem = AnalyticMemoryEstimator(delta_bytes=819200.0, m_available=50e9)
    reqs = _requests([10] * 15 + [1024])
    batches = dp_batch(reqs, 128, est, mem)
    assert len(batches) >= 2  # the long request must be split off
    together = est.t_serve(16, 1024, 128)
    assert sum(b.est_time for b in batches) < together


def test_fcfs_batching_is_arrival_ordered():
    reqs = [Request(rid=i, arrival=float(10 - i), input_len=8, gen_len=4)
            for i in range(6)]
    batches = fcfs_batch(reqs, 4, 16)
    assert [r.rid for r in batches[0].requests] == [5, 4, 3, 2]


# ---------------------------------------------------------------------------
# offloader (max-min, Eq. 11)
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=30),
       st.integers(2, 8))
def test_maxmin_load_gap_bounded(times, n_workers):
    off = MaxMinOffloader(n_workers)
    batches = [Batch(requests=[], input_len=1, slice_len=1, est_time=t)
               for t in times]
    off.assign(batches)
    loads = list(off.loads.values())
    # LPT bound: gap between max and min load <= largest job
    assert max(loads) - min(loads) <= max(times) + 1e-9


def test_maxmin_beats_round_robin_on_skewed_load():
    times = [100.0, 1.0, 1.0, 1.0, 100.0, 1.0, 1.0, 1.0]
    mm, rr = MaxMinOffloader(2), RoundRobinOffloader(2)
    bs = lambda: [Batch(requests=[], input_len=1, slice_len=1, est_time=t) for t in times]
    mm.assign(bs())
    rr.assign(bs())
    gap = lambda o: max(o.loads.values()) - min(o.loads.values())
    assert gap(mm) < gap(rr)


def test_completion_subtracts_estimate():
    off = MaxMinOffloader(2)
    off.assign([Batch(requests=[], input_len=1, slice_len=1, est_time=5.0)])
    w = max(off.loads, key=off.loads.get)
    off.on_batch_complete(w, 5.0)
    assert off.loads[w] == 0.0


# ---------------------------------------------------------------------------
# adaptive interval (Eq. 12)
# ---------------------------------------------------------------------------
def test_interval_floor_and_scaling():
    assert next_interval(0.0, 0.5, 3.0) == 3.0     # Γ floor
    assert next_interval(100.0, 0.5, 3.0) == 50.0  # λ · min load


# ---------------------------------------------------------------------------
# strategy presets
# ---------------------------------------------------------------------------
def test_strategy_presets_match_paper_ablation():
    s = {n: make_strategy(n) for n in ALL_STRATEGIES}
    assert not s["sls"].slices and s["so"].slices
    assert s["sls"].mode == "perreq" and s["ils"].mode == "continuous"
    assert s["pm"].dp_cap is not None and s["ab"].dp_cap is None
    assert s["lb"].offload == "maxmin" and s["ab"].offload == "rr"
    assert s["scls"].adaptive_interval and not s["lb"].adaptive_interval
    # prediction-aware strategies (repro.predict)
    assert s["scls-pred"].mode == "pred" and s["oracle"].mode == "pred"
    assert s["scls-pred"].predictor == "histogram"
    assert s["oracle"].predictor == "perfect"
    assert make_strategy("scls-pred", predictor="proxy").predictor == "proxy"


# ---------------------------------------------------------------------------
# envelope-exact packing (PR 10): per-request block envelopes in the DP
# ---------------------------------------------------------------------------
def _paged_mem(m_available=3e5, page_tokens=16, delta=100.0, zeta=1.0):
    return PagedMemoryEstimator(delta_bytes=delta, m_available=m_available,
                                page_tokens=page_tokens, zeta=zeta)


def test_fits_envelope_bounds_and_unbounded_pool():
    mem = _paged_mem()
    assert mem.fits_envelope(0)
    assert mem.fits_envelope(mem.free_blocks)
    assert not mem.fits_envelope(mem.free_blocks + 1)
    # Δ = 0: the pool cannot bind; callers cap N themselves
    free = PagedMemoryEstimator(delta_bytes=0.0, m_available=1e9)
    assert free.total_blocks == 0 and free.fits_envelope(10**9)


def test_envelope_packing_requires_paged_estimator():
    est = _est()
    amem = AnalyticMemoryEstimator(delta_bytes=100.0, m_available=3e5)
    with pytest.raises(ValueError, match="PagedMemoryEstimator"):
        dp_batch(_requests([8, 16]), 32, est, amem, packing="envelope")
    with pytest.raises(ValueError, match="packing"):
        dp_batch(_requests([8]), 32, est, _paged_mem(), packing="tetris")


def test_envelope_packs_strictly_tighter_on_near_equal_lengths():
    """Near-equal lengths, page pool one block shy of N x blocks_max:
    batch-max charges every member the longest envelope (4 x 31 = 124
    blocks) and must split [2, 2]; the exact per-request sum (29 + 30 +
    31 + 31 = 121) fits the 121-block pool, so envelope packs all four
    in one batch at strictly lower total estimated time."""
    est = _est()
    S, pg = 64, 16
    mem = _paged_mem(m_available=121 * pg * 100.0, page_tokens=pg)
    reqs = _requests([400, 410, 420, 430])
    bm = dp_batch(reqs, S, est, mem)
    env = dp_batch(reqs, S, est, mem, packing="envelope")
    assert sorted(b.size for b in bm) == [2, 2]
    assert [b.size for b in env] == [4]
    assert sum(b.est_time for b in env) < sum(b.est_time for b in bm)
    for b in env:
        assert batch_fits(b, mem, "envelope")
        assert not mem.fits(b.size, b.input_len, S)  # batch-max rejects it


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(1, 512), min_size=1, max_size=10),
       st.sampled_from([8, 16, 32]), st.sampled_from([8, 64, 128]),
       st.sampled_from([2e4, 1e5, 5e5]))
def test_envelope_packing_property(lens, pg, S, m_ava):
    """Satellite acceptance (Hypothesis): envelope-exact packing (a) never
    admits a batch whose summed blocks_for(L_j + S) exceeds the free
    blocks, and (b) is always >= as permissive as the batch-max bound —
    every batch-max-feasible batch is envelope-feasible, hence the DP
    optimum over the larger feasible set is never worse."""
    est = _est()
    mem = _paged_mem(m_available=m_ava, page_tokens=pg)
    reqs = _requests(lens)
    env = dp_batch(reqs, S, est, mem, packing="envelope")
    for b in env:
        total = sum(mem.blocks_per_request(r.effective_input_len, S)
                    for r in b.requests)
        if b.size > 1:  # singleton batches are admitted unconditionally,
            assert total <= mem.free_blocks  # exactly like batch-max
    bm = dp_batch(reqs, S, est, mem)
    for b in bm:
        if b.size > 1:
            assert batch_fits(b, mem, "envelope"), \
                "a batch-max-feasible batch must be envelope-feasible"
    assert (sum(b.est_time for b in env)
            <= sum(b.est_time for b in bm) + 1e-9)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 256), min_size=1, max_size=8),
       st.integers(1, 4))
def test_envelope_respects_explicit_cap(lens, cap):
    est = _est()
    mem = _paged_mem(m_available=1e6)
    batches = dp_batch(_requests(lens), 32, est, mem, max_batch_size=cap,
                       packing="envelope")
    assert all(b.size <= cap for b in batches)


def test_make_strategy_packing_validation():
    s = make_strategy("scls", kv_layout="paged", packing="envelope")
    assert s.packing == "envelope"
    assert make_strategy("scls").packing == "batch-max"
    with pytest.raises(ValueError, match="paged"):
        make_strategy("scls", packing="envelope")  # dense layout
    with pytest.raises(ValueError, match="packing"):
        make_strategy("scls", kv_layout="paged", packing="exact")
