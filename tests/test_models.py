"""Per-architecture smoke tests (reduced configs, deliverable f) and
cache/decode consistency properties shared by every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import get_model

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, T=12, lengths=(12, 7)):
    batch = {"tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
             "lengths": jnp.asarray(lengths, jnp.int32)}
    if cfg.family == "encdec":
        batch["src_embeds"] = jax.random.normal(KEY, (B, 8, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_prefix_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_forward_and_decode(arch):
    """One forward/train step + prefill + decode on CPU: shapes + no NaNs."""
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 5 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)

    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    last, cache = model.prefill(params, batch, 24)
    assert last.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(last).all())

    tok = jnp.argmax(last, -1).astype(jnp.int32)
    for step in range(3):
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.asarray(step, jnp.int32))
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_updates_params(arch):
    """One real optimizer step decreases nothing NaN and changes params."""
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import AdamWConfig, init_adamw

    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = model.init(KEY)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1)))
    batch = make_batch(cfg)
    new_params, new_opt, loss = step(params, opt, batch)
    assert bool(jnp.isfinite(loss))
    assert int(new_opt.step) == 1
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma-2b", "mixtral-8x22b",
                                  "deepseek-v2-lite-16b", "mamba2-130m",
                                  "recurrentgemma-9b"])
def test_decode_matches_forward(arch):
    """Greedy decode with cache == argmax of full forward (KV-cache parity)."""
    cfg = get_config(arch, reduced=True)
    model = get_model(cfg)
    params = model.init(KEY)
    B, T = 1, 9
    toks = jax.random.randint(KEY, (B, T), 2, cfg.vocab_size)
    batch = {"tokens": toks, "lengths": jnp.array([T])}
    last, cache = model.prefill(params, batch, T + 8)
    cur = jnp.argmax(last, -1).astype(jnp.int32)
    seq = [toks]
    for step in range(4):
        seq.append(cur[:, None])
        logits, cache = model.decode_step(params, cache, cur,
                                          jnp.asarray(step, jnp.int32))
        # reference: full forward over the extended sequence
        full = jnp.concatenate(seq, axis=1)
        if cfg.family == "moe":
            from repro.models import moe
            ref = moe.forward(params, cfg, full)[0][:, -1]
        elif cfg.family == "ssm":
            from repro.models import mamba2
            ref = mamba2.forward(params, cfg, full)[:, -1]
        elif cfg.family == "hybrid":
            from repro.models import rglru
            ref = rglru.forward(params, cfg, full)[:, -1]
        else:
            from repro.models import transformer
            ref = transformer.forward(params, cfg, full)[:, -1]
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   atol=3e-3, rtol=1e-3)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)


def test_left_pad_invariance_dense():
    """Logits for a request must not depend on how much left padding it got."""
    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 5), 2, cfg.vocab_size)
    l1, _ = model.prefill(params, {"tokens": toks, "lengths": jnp.array([5])}, 12)
    padded = jnp.pad(toks, ((0, 0), (7, 0)))
    l2, _ = model.prefill(params, {"tokens": padded, "lengths": jnp.array([5])}, 20)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)


def test_chunked_attention_equals_dense():
    from repro.models import attention as attn
    k0 = jax.random.PRNGKey(3)
    q = jax.random.normal(k0, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (2, 64, 2, 16))
    lengths = jnp.array([64, 40])
    idx = jnp.arange(64)[None]
    pos = jnp.where(idx < 64 - lengths[:, None], -1, idx - (64 - lengths[:, None]))
    for window, prefix in [(None, 0), (16, 0), (None, 8)]:
        m = attn.prefill_mask(pos, window)
        if prefix:
            pk, pq = pos[:, None, :], pos[:, :, None]
            m = m | ((pk >= 0) & (pk < prefix) & (pq >= 0))[:, None]
        o1 = attn.gqa_attend(q, k, v, m, 0.25)
        o2 = attn.gqa_attend_chunked(q, k, v, 0.25, pos, pos, window, prefix,
                                     block_q=16)
        valid = (pos >= 0)[..., None, None]
        np.testing.assert_allclose(np.asarray(o1 * valid), np.asarray(o2 * valid),
                                   atol=2e-5)


def test_mamba_chunked_scan_equals_recurrence():
    from repro.models import mamba2
    cfg = get_config("mamba2-130m", reduced=True)
    params = mamba2.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 10), 2, cfg.vocab_size)
    last, _ = mamba2.prefill(params, cfg, toks, jnp.array([10]))
    d_in, H, P, N, G, conv_dim = mamba2._dims(cfg)
    c = mamba2.MambaCache(conv=jnp.zeros((cfg.n_layers, 1, cfg.ssm_conv_width - 1, conv_dim)),
                          state=jnp.zeros((cfg.n_layers, 1, H, P, N)),
                          lengths=jnp.array([0]))
    lg = None
    for t in range(10):
        lg, c = mamba2.decode_step(params, cfg, c, toks[0:1, t], jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(last), atol=5e-3, rtol=1e-3)


def test_sliding_window_ring_cache_long_decode():
    """Ring cache at window W gives identical logits to windowed forward."""
    from repro.models import transformer
    cfg = get_config("llama3.2-1b", reduced=True).replace(sliding_window=6)
    model = get_model(cfg)
    params = model.init(KEY)
    seq = jax.random.randint(KEY, (1, 9), 2, cfg.vocab_size)
    last, cache = model.prefill(params, {"tokens": seq, "lengths": jnp.array([9])}, 6)
    assert cache.k.shape[2] == 6  # ring limited to the window
    cur = jnp.argmax(last, -1).astype(jnp.int32)
    toks = [seq]
    for step in range(6):
        toks.append(cur[:, None])
        lg, cache = model.decode_step(params, cache, cur, jnp.asarray(step, jnp.int32))
        ref = transformer.forward(params, cfg, jnp.concatenate(toks, 1))[:, -1]
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref), atol=2e-3, rtol=1e-3)
        cur = jnp.argmax(lg, -1).astype(jnp.int32)


def test_mla_cache_is_compressed():
    """DeepSeek MLA cache must store latents, not full K/V (the arch's point)."""
    cfg = get_config("deepseek-v2-lite-16b", reduced=True)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    _, cache = model.prefill(params, batch, 20)
    ckv = cache.kv.ckv
    assert ckv.shape[-1] == cfg.kv_lora_rank
    naive = 2 * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    assert cfg.kv_lora_rank + cfg.qk_rope_head_dim < naive


def test_kv_bytes_per_token_accounting():
    cfg = get_config("llama3.2-1b")
    model = get_model(cfg)
    # full GQA: 2 * L * kv * hd * 2 bytes
    assert model.kv_bytes_per_token() == 2 * 16 * 8 * 64 * 2
    # sharding 8 kv heads over 16 model shards caps at 8
    assert model.kv_bytes_per_token(16) == model.kv_bytes_per_token() / 8
    mla = get_model(get_config("deepseek-v2-lite-16b"))
    assert mla.kv_bytes_per_token() == 27 * (512 + 64) * 2
