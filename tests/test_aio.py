"""repro.serving.aio + repro.serving.admission: the concurrent async
front end (N clients over one SchedulerCore), SLO-aware admission, and
shutdown/cancellation semantics under concurrency — on both backends."""
import asyncio

import numpy as np
import pytest

from repro.core.memory import (AnalyticMemoryEstimator, LLAMA2_13B_DELTA,
                               PagedMemoryEstimator)
from repro.serving import (AdmissionController, AdmissionRejected,
                           NO_ADMISSION, ServingConfig,
                           default_sim_environment, predicted_queue_delay)


@pytest.fixture(scope="module")
def sim_env():
    return default_sim_environment("hf")  # analytic memory model


def _sim_aio(sim_env, **cfg_kw):
    true_lat, est, mem = sim_env
    kw = dict(strategy="scls", workers=2, slice_len=64, gamma=1.0)
    kw.update(cfg_kw)
    return ServingConfig(**kw).build_sim(true_lat, est, mem).aio


# ---------------------------------------------------------------------------
# tentpole acceptance: concurrent clients over one core
# ---------------------------------------------------------------------------
def test_two_concurrent_clients_interleave_in_one_slice_batch(sim_env):
    """Two clients submitted concurrently must be batched TOGETHER by the
    central tick — asserted on the dispatch log, the same fingerprint the
    golden-equivalence test pins."""
    server = _sim_aio(sim_env)

    async def client(i):
        h = server.submit(input_len=64, gen_len=100, arrival=0.0)
        toks = [t async for t in h.tokens()]
        assert toks == list(range(100))
        r = await h.result()
        assert r.done
        return h.rid

    async def main():
        rids = await asyncio.gather(client(0), client(1))
        m = await server.close()
        return rids, m

    rids, m = asyncio.run(main())
    assert m.n_completed == 2
    shared = [e for e in server.core.batch_log
              if e[0] == "static" and set(rids) <= set(e[2])]
    assert shared, (f"clients {rids} never shared a slice batch: "
                    f"{server.core.batch_log}")


def test_async_slices_stream_one_chunk_per_slice(sim_env):
    """slices() must reproduce the true slice chunking even when consumed
    after the fact (slice boundaries are recorded as they happen) — the
    guarantee the SSE endpoint's chunk-per-slice contract rests on."""
    server = _sim_aio(sim_env)

    async def main():
        h = server.submit(input_len=64, gen_len=200, arrival=0.0)
        await h.result()  # everything completes before we consume
        chunks = [c async for c in h.slices()]
        return h, chunks

    h, chunks = asyncio.run(main())
    assert h.request.n_schedules == len(chunks)
    assert [t for c in chunks for t in c] == list(range(200))
    assert all(len(c) <= 64 for c in chunks)


def test_many_clients_mixed_lifecycles(sim_env):
    """Submits, streams, cancels, and awaits interleaved across many
    clients complete without cross-talk."""
    server = _sim_aio(sim_env, workers=4)

    async def streamer(i):
        h = server.submit(input_len=32 + i, gen_len=120)
        return [t async for t in h.tokens()], h

    async def canceller(i):
        h = server.submit(input_len=48 + i, gen_len=300)
        async for t in h.tokens():
            if t >= 64:  # after its first slice completes
                h.cancel()
                break
        await h.result()
        return h

    async def main():
        res = await asyncio.gather(*(streamer(i) for i in range(6)),
                                   *(canceller(i) for i in range(2)))
        m = await server.close()
        return res, m

    res, m = asyncio.run(main())
    for toks, h in res[:6]:
        assert h.done and toks == list(range(120))
    for h in res[6:]:
        assert h.cancelled and not h.done
        assert 0 < h.request.generated < 300
    assert m.n_completed == 6


# ---------------------------------------------------------------------------
# shutdown semantics under concurrency
# ---------------------------------------------------------------------------
def test_drain_with_inflight_streams_completes_them(sim_env):
    server = _sim_aio(sim_env)

    async def consumer(h):
        return [t async for t in h.tokens()]

    async def main():
        handles = [server.submit(input_len=64, gen_len=150,
                                 arrival=0.5 * i) for i in range(3)]
        streams = [asyncio.ensure_future(consumer(h)) for h in handles]
        m = await server.drain()          # concurrent with the streams
        token_lists = await asyncio.gather(*streams)
        return m, handles, token_lists

    m, handles, token_lists = asyncio.run(main())
    assert m.n_completed == 3
    assert all(h.done for h in handles)
    assert all(toks == list(range(150)) for toks in token_lists)


def test_close_refuses_new_submissions_and_stops_pacer(sim_env):
    server = _sim_aio(sim_env)

    async def main():
        h = server.submit(input_len=16, gen_len=30)
        m = await server.close()
        assert h.done and m.n_completed == 1
        with pytest.raises(RuntimeError, match="closed"):
            server.submit(input_len=8, gen_len=4)
        assert server._task is None  # pacer gone

    asyncio.run(main())


def test_pacer_failure_propagates_to_waiters(sim_env):
    """A backend/step failure must not strand clients on events that
    never fire: every waiter re-raises the pacer's exception."""
    server = _sim_aio(sim_env)

    class Boom(RuntimeError):
        pass

    orig = server.core.backend.run_batch

    def exploding(*a, **kw):
        raise Boom("engine fell over")

    server.core.backend.run_batch = exploding
    try:
        async def main():
            h = server.submit(input_len=64, gen_len=100)
            with pytest.raises(Boom):
                await h.result()
            with pytest.raises(Boom):
                await server.drain()

        asyncio.run(main())
    finally:
        server.core.backend.run_batch = orig


def test_pacer_restarts_clean_after_failure(sim_env):
    """One failed step must not poison the server forever: once the
    fault is gone, a fresh submit restarts the pacer and new requests
    serve normally."""
    server = _sim_aio(sim_env)
    orig = server.core.backend.run_batch

    def exploding(*a, **kw):
        raise RuntimeError("transient engine fault")

    async def main():
        server.core.backend.run_batch = exploding
        h = server.submit(input_len=64, gen_len=50)
        with pytest.raises(RuntimeError, match="transient"):
            await h.result()
        server.core.backend.run_batch = orig
        h2 = server.submit(input_len=32, gen_len=40)
        r = await h2.result()
        assert r.done and r.generated == 40
        assert server._pacer_exc is None

    asyncio.run(main())


def test_slow_consumer_receives_final_slice_tokens(sim_env):
    """A consumer that awaits between yields (any real socket writer)
    must still receive the tokens of the slice that finalized the
    request — the snapshot it iterates goes stale while it sleeps."""
    server = _sim_aio(sim_env)

    async def main():
        h = server.submit(input_len=64, gen_len=200)
        toks = []
        async for t in h.tokens():
            toks.append(t)
            await asyncio.sleep(0)  # yield to the pacer between tokens
        return toks

    toks = asyncio.run(main())
    assert toks == list(range(200))


def test_finished_handles_are_released(sim_env):
    """Serve-forever deployments must not leak one handle per request:
    terminal requests leave the server's registry."""
    server = _sim_aio(sim_env)

    async def main():
        hs = [server.submit(input_len=32, gen_len=20) for _ in range(5)]
        await asyncio.gather(*(h.result() for h in hs))
        return hs

    hs = asyncio.run(main())
    assert server._handles == {}
    # ...but completed handles keep working standalone
    assert all(h.done and h.output_tokens == list(range(20)) for h in hs)


def test_cancel_racing_slice_completion_sim(sim_env):
    """Cancel issued while the slice-completion event is already queued:
    the slice's tokens land, the request finalizes exactly once as
    cancelled, and nothing leaks (offloader load decays to zero)."""
    server = _sim_aio(sim_env)
    h = server.submit(input_len=64, gen_len=500)
    core = server.core

    def completion_queued(rid):
        return any(kind == "batch_done"
                   and any(r.rid == rid for r in payload[1].requests)
                   for _, _, kind, payload in core._events)

    while not completion_queued(h.rid):   # sync drive: no loop running
        assert core.step()
    assert h.cancel()
    core.run_until_idle()
    assert h.cancelled and not h.done and h.finished
    assert 0 < h.request.generated < 500  # the in-flight slice landed
    assert core.is_finalized(h.rid)
    assert max(core.offloader.loads.values()) == pytest.approx(0.0, abs=1e-9)
    m = core.metrics()
    assert m.n_completed == 0 and m.n_requests == 1


# ---------------------------------------------------------------------------
# SLO-aware admission (sim)
# ---------------------------------------------------------------------------
def test_rejected_request_leaves_no_trace_sim(sim_env):
    """A rejected request must never reach the scheduler: no Request
    registered, no dispatch, no paged block accounting — only the
    n_rejected counter moves."""
    true_lat, est, _ = sim_env
    mem = PagedMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                               m_available=5e9, zeta=0.9, page_tokens=16)
    server = ServingConfig(strategy="scls", kv_layout="paged", workers=2,
                           slice_len=64, gamma=1.0).build_sim(
        true_lat, est, mem).aio
    with pytest.raises(AdmissionRejected) as ei:
        server.submit(input_len=512, gen_len=800, slo_ms=1.0)
    d = ei.value.decision
    assert not d.accept and d.retry_after > 0
    assert "deadline" in d.reason
    assert server.core.requests == [] and server.core.batch_log == []
    assert server.core.pool == [] and not server.core._by_rid
    assert server.n_rejected == 1 and server.n_submitted == 0
    m = server.metrics()
    assert m.n_rejected == 1 and m.n_requests == 0

    # a best-effort request (no SLO) on the same server is admitted
    h = server.submit(input_len=512, gen_len=10)
    assert h.request.deadline is None


def test_admission_degrade_clamps_generation_budget(sim_env):
    """allow_degrade=True admits with the longest budget that still meets
    the deadline instead of rejecting."""
    server = _sim_aio(sim_env)
    with pytest.raises(AdmissionRejected):
        server.submit(input_len=64, gen_len=600, slo_ms=8_000)
    h = server.submit(input_len=64, gen_len=600, slo_ms=8_000,
                      allow_degrade=True)
    assert 1 <= h.request.max_gen < 600
    assert h.request.gen_len == h.request.max_gen
    assert server.n_degraded == 1

    async def main():
        return await h.result()

    r = asyncio.run(main())
    assert r.done and r.generated == h.request.max_gen
    assert r.finish_time <= r.deadline  # the degraded budget met its SLO


def test_predicted_queue_delay_tracks_load(sim_env):
    server = _sim_aio(sim_env)
    empty = predicted_queue_delay(server.core)
    assert empty == 0.0
    for i in range(8):
        server.submit(input_len=256, gen_len=400)
    # requests sit in arrival events/pool until stepped; force intake
    for _ in range(10):
        server.core.step()
    loaded = predicted_queue_delay(server.core)
    assert loaded > empty
    # the dry-run decision folds that delay into its completion estimate
    dec = server.check_admission(input_len=64, gen_len=100, slo_ms=600_000)
    assert dec.accept and dec.predicted_completion >= loaded


def test_default_slo_from_config_sets_deadline(sim_env):
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2,
                           slo_ms=45_000).build_sim(true_lat, est, mem)
    h = server.submit(input_len=32, gen_len=20)
    assert h.request.deadline == pytest.approx(45.0)
    server.drain()
    assert server.metrics().slo_attainment == 1.0


def test_slo_attainment_counts_missed_deadlines(sim_env):
    """With admission disabled, recorded deadlines still score attainment
    — every deadline here is blown, so attainment is 0."""
    server = _sim_aio(sim_env)
    server.admission = NO_ADMISSION
    server.default_slo_ms = 0.5  # 0.5 ms: unmeetable, but never enforced
    for i in range(3):
        server.submit(input_len=64, gen_len=100)
    server.core.run_until_idle()
    m = server.metrics()
    assert m.n_completed == 3 and m.n_rejected == 0
    assert m.slo_attainment == 0.0


def test_admission_controller_validation():
    with pytest.raises(ValueError, match="headroom"):
        AdmissionController(headroom=0.0)
    with pytest.raises(ValueError, match="time_scale"):
        ServingConfig(strategy="scls", time_scale=-1.0)
    with pytest.raises(ValueError, match="sim"):
        ServingConfig(strategy="scls", backend="real", time_scale=2.0)
    with pytest.raises(ValueError, match="slo_ms"):
        ServingConfig(strategy="scls", slo_ms=0.0)
    with pytest.raises(ValueError, match="http_port"):
        ServingConfig(strategy="scls", http_port=70_000)


def test_paced_server_maps_virtual_to_wall_time(sim_env):
    """time_scale=k serves virtual second t at wall second t/k."""
    import time
    server = _sim_aio(sim_env, time_scale=100.0, gamma=1.0)

    async def main():
        t0 = time.monotonic()
        h = server.submit(input_len=32, gen_len=100)
        await h.result()
        return time.monotonic() - t0, h

    wall, h = asyncio.run(main())
    virt = h.request.finish_time - h.request.arrival
    # wall time must be at least the virtual span compressed by the scale
    # (pacing sleeps), but nowhere near the uncompressed virtual time
    assert wall >= virt / 100.0 * 0.5
    assert wall < max(virt, 1.0)


# ---------------------------------------------------------------------------
# real backend: admission/cancel/drain with real engines + allocators
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def real_env():
    import jax
    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.models.registry import get_model
    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2, repeats=1)
    return arch, model, params, est


def _real_server(real_env, n_engines=2, **cfg_kw):
    from repro.engine.static_engine import StaticEngine
    arch, model, params, est = real_env
    kw = dict(strategy="scls", backend="real", kv_layout="paged",
              page_tokens=16, slice_len=8, max_gen=24, gamma=0.25,
              m_available=64e6, mem_bucket=8)
    kw.update(cfg_kw)
    scfg = ServingConfig(**kw)
    mem = scfg.memory_estimator(model.kv_bytes_per_token())
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
               for _ in range(n_engines)]
    return scfg.build_real(engines, est, mem)


def test_real_backend_rejected_request_never_reserves_pages(real_env):
    """Satellite acceptance: a 429-equivalent rejection happens before
    any prefill or page reservation — every allocator's free-block count
    is untouched and the engines never ran."""
    arch, model, params, est = real_env
    server = _real_server(real_env)
    allocators = server.core.backend.allocators
    baseline = [a.free_blocks for a in allocators]
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, arch.vocab_size, size=16).astype(np.int32)
    with pytest.raises(AdmissionRejected):
        server.submit(prompt, gen_len=20, max_gen=24, slo_ms=0.001)
    assert [a.free_blocks for a in allocators] == baseline
    assert all(not a.owners() for a in allocators)
    assert server.core.requests == [] and server.core.batch_log == []
    assert server.core.n_rejected == 1
    # the same request without the impossible SLO is served for real
    h = server.submit(prompt, gen_len=6, max_gen=24, slo_ms=600_000)
    r = h.result()
    assert r.done and r.generated == 6
    assert [a.free_blocks for a in allocators] == baseline


def test_real_backend_async_clients_and_drain(real_env):
    """Concurrent asyncio clients over REAL engines: streams interleave,
    one cancel races its slice, drain leaves no pages behind."""
    arch, model, params, est = real_env
    server = _real_server(real_env)
    allocators = server.core.backend.allocators
    baseline = [a.free_blocks for a in allocators]
    rng = np.random.default_rng(1)
    aio = server.aio

    def prompt(n):
        return rng.integers(0, arch.vocab_size, size=n).astype(np.int32)

    async def streamer(i):
        h = aio.submit(prompt(8 + i), gen_len=10 + i, max_gen=24,
                       arrival=0.1 * i)
        toks = [t async for t in h.tokens()]
        return h, toks

    async def canceller():
        h = aio.submit(prompt(16), gen_len=20, max_gen=24)
        async for _ in h.tokens():
            h.cancel()   # first token observed: hang up mid-request
            break
        await h.result()
        return h

    async def main():
        res = await asyncio.gather(streamer(0), streamer(1), canceller())
        m = await aio.drain()
        return res, m

    (s0, s1, hc), m = asyncio.run(main())
    for i, (h, toks) in enumerate((s0, s1)):
        assert h.done and len(toks) == 10 + i
        assert toks == h.request.output_tokens
    assert hc.cancelled and hc.request.generated < 20
    assert m.n_completed == 2
    assert [a.free_blocks for a in allocators] == baseline
    assert all(not a.owners() for a in allocators)
