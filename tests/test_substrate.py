"""Substrate tests: optimizer, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticCorpus, TokenBatcher
from repro.optim.adamw import (AdamWConfig, adamw_update, global_norm,
                               init_adamw, lr_schedule)


def test_adamw_converges_on_quadratic():
    """Minimize ||x - target||^2 — must get close in a few hundred steps."""
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=10, total_steps=400)
    state = init_adamw(params)
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    for _ in range(400):
        g = jax.grad(loss)(params)
        params, state = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-3)


def test_grad_clip_bounds_update():
    params = {"x": jnp.zeros(4)}
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0)
    state = init_adamw(params)
    huge = {"x": jnp.full(4, 1e9)}
    new, _ = adamw_update(cfg, huge, state, params)
    assert float(jnp.abs(new["x"]).max()) < 1.0


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_pipeline_deterministic_and_shaped():
    c = SyntheticCorpus(vocab_size=128, seed=7)
    b1 = TokenBatcher(c, batch_size=4, seq_len=16)
    b2 = TokenBatcher(c, batch_size=4, seq_len=16)
    x1, x2 = next(b1), next(b2)
    np.testing.assert_array_equal(x1["tokens"], x2["tokens"])
    assert x1["tokens"].shape == (4, 16)
    assert x1["tokens"].min() >= 2 and x1["tokens"].max() < 128
    # stepping changes data; restore() rewinds
    y = next(b1)
    assert not np.array_equal(x1["tokens"], y["tokens"])
    b1.restore({"step": 0})
    np.testing.assert_array_equal(next(b1)["tokens"], x1["tokens"])


def test_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_config
    from repro.models.registry import get_model
    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, opt, step=42, extra={"note": "hi"})
    p2, o2, step, extra = load_checkpoint(path, params, opt)
    assert step == 42 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
