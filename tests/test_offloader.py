"""Offloading (paper §4.5): Eq. 11 load bookkeeping, max-min vs
round-robin balance, and placement-safety properties."""
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.offloader import MaxMinOffloader, RoundRobinOffloader
from repro.core.request import Batch, Request


def _batch(rid: int, est_time: float) -> Batch:
    r = Request(rid=rid, arrival=0.0, input_len=8, gen_len=4)
    return Batch(requests=[r], input_len=8, slice_len=4, est_time=est_time)


# ---------------------------------------------------------------------------
# Eq. 11: load(w) += est on assign, -= est on completion (decay), so the
# estimation error never accumulates across serving rounds
# ---------------------------------------------------------------------------
def test_eq11_load_accumulates_on_assign():
    off = MaxMinOffloader(2)
    out = off.assign([_batch(0, 3.0), _batch(1, 2.0), _batch(2, 1.0)])
    assert sorted(off.loads.values()) == [3.0, 3.0]  # 3 vs 2+1 (max-min)
    assert len(out) == 3


def test_eq11_decay_on_batch_complete():
    off = MaxMinOffloader(2)
    off.assign([_batch(0, 3.0), _batch(1, 2.0)])
    off.on_batch_complete(0, 3.0)
    assert off.loads[0] == 0.0
    off.on_batch_complete(1, 2.0)
    assert all(v == 0.0 for v in off.loads.values())
    assert off.min_load() == 0.0


def test_eq11_decay_clamps_at_zero():
    """Over-subtraction (completion reported with a larger estimate than
    was ever added) must clamp, not drive the load negative — a negative
    load would poison Eq. 12's min-load interval forever."""
    off = RoundRobinOffloader(2)
    off.assign([_batch(0, 1.0)])
    off.on_batch_complete(0, 5.0)
    assert off.loads[0] == 0.0


# ---------------------------------------------------------------------------
# max-min vs round-robin imbalance (the Eq. 12 min-load signal / Fig. 17)
# ---------------------------------------------------------------------------
def _spread(loads):
    vals = np.array(list(loads.values()))
    return float(vals.max() - vals.min())


def test_maxmin_balances_heterogeneous_batches_better_than_rr():
    """The paper's motivating case: a few long batches among many short
    ones.  Round-robin lands long batches on whichever worker is next;
    max-min places longest-first onto the least-loaded worker."""
    times = [8.0, 1.0, 1.0, 1.0, 7.0, 1.0, 1.0, 1.0]
    mm, rr = MaxMinOffloader(4), RoundRobinOffloader(4)
    mm.assign([_batch(i, t) for i, t in enumerate(times)])
    rr.assign([_batch(i, t) for i, t in enumerate(times)])
    assert _spread(mm.loads) < _spread(rr.loads)
    # max-min is provably within max(est) of perfect balance here
    assert _spread(mm.loads) <= max(times)
    # and the min-load signal Eq. 12 feeds on is higher (no starved worker)
    assert mm.min_load() >= rr.min_load()


def test_maxmin_sorts_longest_first():
    off = MaxMinOffloader(2)
    out = off.assign([_batch(0, 1.0), _batch(1, 10.0), _batch(2, 5.0)])
    # longest (10) placed first on an empty worker, 5 on the other, 1 after
    est_order = [b.est_time for _, b in out]
    assert est_order == [10.0, 5.0, 1.0]
    assert sorted(off.loads.values()) == [6.0, 10.0]


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.0, 100.0), min_size=0, max_size=30),
       st.integers(1, 8), st.booleans())
def test_assignments_never_exceed_worker_count(times, n_workers, use_maxmin):
    """Property: every batch is assigned exactly once, to a worker id in
    [0, n_workers), no matter the batch mix or worker count."""
    off = (MaxMinOffloader if use_maxmin else RoundRobinOffloader)(n_workers)
    batches = [_batch(i, t) for i, t in enumerate(times)]
    out = off.assign(batches)
    assert len(out) == len(batches)
    assert {id(b) for _, b in out} == {id(b) for b in batches}
    assert all(0 <= w < n_workers for w, _ in out)
    assert set(off.loads) == set(range(n_workers))
    # conservation: total load == total estimated time (Eq. 11 additions)
    assert sum(off.loads.values()) == pytest.approx(sum(times))


# ---------------------------------------------------------------------------
# retention-affinity epsilon-tiebreak (PR 7): a worker holding the batch's
# resident prefix pages wins placement only within epsilon * est_time of
# the Eq. 11 minimum — affinity never overrides real imbalance
# ---------------------------------------------------------------------------
def test_affinity_tiebreak_prefers_resident_worker_within_epsilon():
    off = MaxMinOffloader(2, epsilon=0.25)
    off.loads = {0: 0.0, 1: 0.1}
    off.affinity_fn = lambda b: 1
    [(w, _)] = off.assign([_batch(0, 1.0)])
    assert w == 1                                      # 0.1 <= 0.0 + 0.25*1.0
    assert off.loads == {0: 0.0, 1: 1.1}               # Eq. 11 charged there


def test_affinity_tiebreak_yields_to_real_imbalance():
    off = MaxMinOffloader(2, epsilon=0.25)
    off.loads = {0: 0.0, 1: 0.5}
    off.affinity_fn = lambda b: 1
    [(w, _)] = off.assign([_batch(0, 1.0)])
    assert w == 0                                      # 0.5 > 0.25: balance wins
    # the load the affinity worker would have taken stays bounded: the
    # epsilon contract is |load(pref) - min| <= epsilon * est at override
    off2 = MaxMinOffloader(2, epsilon=0.25)
    off2.affinity_fn = lambda b: 1
    for i in range(8):                                 # every batch prefers w1
        off2.assign([_batch(i, 1.0)])
    assert abs(off2.loads[1] - off2.loads[0]) <= 0.25 * 1.0 + 1.0


def test_affinity_hook_absent_none_or_unknown_changes_nothing():
    plain = MaxMinOffloader(3)
    assert plain.affinity_fn is None and plain.epsilon == 0.25
    armed = MaxMinOffloader(3)
    armed.affinity_fn = lambda b: None                 # nothing resident
    stale = MaxMinOffloader(3)
    stale.affinity_fn = lambda b: 99                   # worker long gone
    batches = [_batch(i, float(3 - i % 3)) for i in range(9)]
    import copy
    want = [(w, b.requests[0].rid)
            for w, b in plain.assign(copy.deepcopy(batches))]
    for off in (armed, stale):
        got = [(w, b.requests[0].rid)
               for w, b in off.assign(copy.deepcopy(batches))]
        assert got == want
        assert off.loads == plain.loads


def test_affinity_epsilon_validated():
    with pytest.raises(ValueError):
        MaxMinOffloader(2, epsilon=-0.1)
    off = MaxMinOffloader(2, epsilon=0.0)              # 0 = exact ties only
    off.loads = {0: 0.0, 1: 0.0}
    off.affinity_fn = lambda b: 1
    [(w, _)] = off.assign([_batch(0, 1.0)])
    assert w == 1
