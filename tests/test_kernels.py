"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.kernels.ref import (decode_attention_ref, flash_prefill_ref,
                               paged_decode_attention_ref)

KEY = jax.random.PRNGKey(0)


def _qkv(B, T, Hq, Hkv, D, dtype=jnp.float32, key=KEY):
    q = jax.random.normal(key, (B, T, Hq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, D), dtype)
    return q, k, v


def _positions(B, T, lengths):
    idx = jnp.arange(T)[None]
    L = jnp.asarray(lengths)[:, None]
    return jnp.where(idx < T - L, -1, idx - (T - L)).astype(jnp.int32)


ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,T,Hq,Hkv,D", [
    (1, 16, 1, 1, 8),
    (2, 32, 4, 2, 16),
    (2, 32, 4, 1, 32),     # MQA
    (1, 64, 8, 8, 16),     # MHA
    (3, 24, 6, 2, 64),     # non-pow2 batch, T%8==0
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_prefill_sweep(B, T, Hq, Hkv, D, dtype, window):
    q, k, v = _qkv(B, T, Hq, Hkv, D, dtype)
    lengths = [T] + [max(1, T - 5)] * (B - 1)
    pos = _positions(B, T, lengths)
    out = flash_prefill(q, k, v, pos, window=window, block_q=8, block_k=8,
                        interpret=True)
    ref = flash_prefill_ref(q, k, v, pos, window=window)
    valid = (pos >= 0)[..., None, None]
    np.testing.assert_allclose(
        np.asarray((out * valid).astype(jnp.float32)),
        np.asarray((ref * valid).astype(jnp.float32)), atol=ATOL[dtype])


@pytest.mark.parametrize("B,W,Hq,Hkv,D", [
    (1, 8, 1, 1, 8),
    (2, 24, 8, 2, 16),
    (4, 16, 4, 1, 32),     # MQA
    (2, 64, 4, 4, 64),     # MHA, long cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 6])
def test_decode_attention_sweep(B, W, Hq, Hkv, D, dtype, window):
    kc = jax.random.normal(KEY, (B, W, Hkv, D), dtype)
    vc = jax.random.normal(jax.random.fold_in(KEY, 1), (B, W, Hkv, D), dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hq, D), dtype)
    rng = np.random.default_rng(0)
    slot_pos = np.full((B, W), -1, np.int32)
    q_pos = []
    for b in range(B):
        fill = rng.integers(1, W + 1)
        slot_pos[b, :fill] = np.arange(fill)
        q_pos.append(fill)
    out = decode_attention(q, kc, vc, jnp.asarray(slot_pos), jnp.asarray(q_pos),
                           window=window, block_w=8, interpret=True)
    ref = decode_attention_ref(q, kc, vc, jnp.asarray(slot_pos),
                               jnp.asarray(q_pos), window=window)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               atol=ATOL[dtype])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 16, 32]),
       st.sampled_from([(4, 2), (2, 1), (4, 4)]), st.sampled_from([8, 16]))
def test_flash_prefill_property(B, T, heads, D):
    """Random shapes: kernel == oracle on all real-token rows."""
    Hq, Hkv = heads
    q, k, v = _qkv(B, T, Hq, Hkv, D)
    lengths = [T - (i % T) for i in range(B)]
    pos = _positions(B, T, lengths)
    out = flash_prefill(q, k, v, pos, block_q=8, block_k=8, interpret=True)
    ref = flash_prefill_ref(q, k, v, pos)
    valid = (pos >= 0)[..., None, None]
    np.testing.assert_allclose(np.asarray(out * valid), np.asarray(ref * valid),
                               atol=5e-5)


def test_ring_cache_decode_kernel():
    """Ring layout (wrapped positions) must be handled purely via slot_pos."""
    B, W, H, D = 1, 8, 2, 16
    kc = jax.random.normal(KEY, (B, W, H, D))
    vc = jax.random.normal(jax.random.fold_in(KEY, 1), (B, W, H, D))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 4, D))
    # cache holds positions 5..12 wrapped: slot i has position (5+i) rotated
    slot_pos = jnp.asarray(np.roll(np.arange(5, 13), 3)[None].astype(np.int32))
    q_pos = jnp.array([12])
    out = decode_attention(q, kc, vc, slot_pos, q_pos, window=6, block_w=4,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, slot_pos, q_pos, window=6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def _paged_setup(B, nb, pg, Hkv, D, P, dtype=jnp.float32, seed=0):
    """Random page pool + block tables: each row fills a random number of
    logical slots, mapped to shuffled non-null pages; unused table entries
    stay at the null page (0) and are masked via slot_pos = -1."""
    kp = jax.random.normal(KEY, (P, pg, Hkv, D), dtype)
    vp = jax.random.normal(jax.random.fold_in(KEY, 1), (P, pg, Hkv, D), dtype)
    rng = np.random.default_rng(seed)
    bt = np.zeros((B, nb), np.int32)
    slot_pos = np.full((B, nb * pg), -1, np.int32)
    q_pos = []
    for b in range(B):
        fill = int(rng.integers(1, nb * pg + 1))
        n_used = -(-fill // pg)
        bt[b, :n_used] = rng.choice(np.arange(1, P), size=n_used, replace=False)
        slot_pos[b, :fill] = np.arange(fill)
        q_pos.append(fill - 1)
    return kp, vp, jnp.asarray(bt), jnp.asarray(slot_pos), jnp.asarray(q_pos)


@pytest.mark.parametrize("B,nb,pg,Hq,Hkv,D", [
    (1, 2, 8, 1, 1, 8),
    (2, 3, 8, 4, 2, 16),
    (4, 2, 8, 4, 1, 32),   # MQA
    (2, 4, 16, 4, 4, 64),  # MHA, long cache
    (3, 3, 8, 6, 2, 16),   # non-pow2 batch
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 6])
def test_paged_decode_attention_sweep(B, nb, pg, Hq, Hkv, D, dtype, window):
    P = B * nb + 1  # enough distinct pages for every row + the null page
    kp, vp, bt, slot_pos, q_pos = _paged_setup(B, nb, pg, Hkv, D, P, dtype)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hq, D), dtype)
    out = paged_decode_attention(q, kp, vp, bt, slot_pos, q_pos,
                                 window=window, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, slot_pos, q_pos,
                                     window=window)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               atol=ATOL[dtype])


def test_paged_equals_dense_on_gathered_cache():
    """The paged kernel over scattered pages == the dense kernel over the
    materialized gather: paging is pure layout, never math."""
    B, nb, pg, Hq, Hkv, D = 2, 3, 8, 4, 2, 16
    P = B * nb + 1
    kp, vp, bt, slot_pos, q_pos = _paged_setup(B, nb, pg, Hkv, D, P)
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hq, D))
    paged = paged_decode_attention(q, kp, vp, bt, slot_pos, q_pos,
                                   interpret=True)
    kc = kp[bt].reshape(B, nb * pg, Hkv, D)
    vc = vp[bt].reshape(B, nb * pg, Hkv, D)
    dense = decode_attention(q, kc, vc, slot_pos, q_pos, block_w=pg,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense), atol=2e-5)


def test_paged_decode_ring_positions():
    """Wrapped (ring) positions must be handled purely via slot_pos, as in
    the dense kernel — the block table stays oblivious."""
    B, nb, pg, H, D = 1, 2, 4, 2, 16
    P = 4
    kp = jax.random.normal(KEY, (P, pg, H, D))
    vp = jax.random.normal(jax.random.fold_in(KEY, 1), (P, pg, H, D))
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 4, D))
    bt = jnp.asarray([[2, 1]], jnp.int32)
    # cache holds positions 5..12 wrapped across the two pages
    slot_pos = jnp.asarray(np.roll(np.arange(5, 13), 3)[None].astype(np.int32))
    q_pos = jnp.array([12])
    out = paged_decode_attention(q, kp, vp, bt, slot_pos, q_pos, window=6,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, slot_pos, q_pos, window=6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_paged_ops_dispatch_xla_equals_pallas():
    B, nb, pg, Hkv, D = 2, 2, 8, 2, 16
    P = B * nb + 1
    kp, vp, bt, slot_pos, q_pos = _paged_setup(B, nb, pg, Hkv, D, P)
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, 4, D))
    a = ops.paged_decode_attention(q, kp, vp, bt, slot_pos, q_pos, impl="xla")
    b = ops.paged_decode_attention(q, kp, vp, bt, slot_pos, q_pos,
                                   impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_ops_dispatch_xla_equals_pallas():
    q, k, v = _qkv(2, 16, 4, 2, 16)
    pos = _positions(2, 16, [16, 10])
    a = ops.prefill_attention(q, k, v, pos, impl="xla")
    b = ops.prefill_attention(q, k, v, pos, impl="pallas", block_q=8, block_k=8)
    valid = (pos >= 0)[..., None, None]
    np.testing.assert_allclose(np.asarray(a * valid), np.asarray(b * valid), atol=2e-5)

    kc = jax.random.normal(KEY, (2, 16, 2, 16))
    vc = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 16, 2, 16))
    qd = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 4, 16))
    slot_pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16)).astype(jnp.int32)
    q_pos = jnp.array([15, 15])
    a = ops.decode_gqa_attention(qd, kc, vc, slot_pos, q_pos, impl="xla")
    b = ops.decode_gqa_attention(qd, kc, vc, slot_pos, q_pos, impl="pallas", block_w=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


@pytest.mark.parametrize("B,T,H,P,N,Q", [
    (1, 8, 1, 4, 4, 4),
    (2, 24, 3, 8, 4, 8),
    (1, 32, 2, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan_sweep(B, T, H, P, N, Q, dtype):
    """SSD Pallas kernel vs the jnp chunked oracle (and hence, transitively,
    vs the exact recurrence — see test_models.py)."""
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models.mamba2 import _ssd_chunked
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, T, H), dtype))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.2)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, 1, N), dtype)
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, T, 1, N), dtype)
    y_ref, st_ref = _ssd_chunked(x, dt, A, Bm, Cm, Q)
    Bh = jnp.broadcast_to(Bm, (B, T, H, N))
    Ch = jnp.broadcast_to(Cm, (B, T, H, N))
    y, st = ssd_scan(x, dt, A, Bh, Ch, Q, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=1e-5)


def test_ssd_ops_dispatch():
    from repro.kernels import ops
    key = jax.random.PRNGKey(2)
    B, T, H, P, N = 1, 16, 2, 8, 4
    x = jax.random.normal(key, (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (B, T, H)))
    A = -jnp.ones((H,))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, T, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, 1, N))
    y1, s1 = ops.ssd_chunked_scan(x, dt, A, Bm, Cm, chunk=8, impl="xla")
    y2, s2 = ops.ssd_chunked_scan(x, dt, A, Bm, Cm, chunk=8, impl="pallas")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)


# ---------------------------------------------------------------------------
# paged prefill write (persistent paged StaticEngine storage)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,pg,Hkv,D", [
    (1, 16, 8, 1, 8),
    (2, 24, 8, 2, 16),
    (3, 12, 4, 2, 8),   # non-pow2 batch, partial last page
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_write_matches_ref_on_observable_slots(B, T, pg, Hkv,
                                                             D, dtype):
    """Pallas and jnp impls agree on every slot a reader can reach (valid
    slot_pos); tail slots of a partial page are masked garbage by
    contract and excluded."""
    rng = np.random.default_rng(0)
    lens = rng.integers(1, T + 1, size=B)
    lens[0] = T  # always one full row
    positions = _positions(B, T, lens)
    nb = -(-T // pg) + 1  # one spare block per row (decode capacity)
    P = B * nb + 1
    k_new = jax.random.normal(KEY, (B, T, Hkv, D), dtype)
    v_new = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, Hkv, D), dtype)
    bt = np.zeros((B, nb), np.int32)
    perm = rng.permutation(np.arange(1, P))
    for b in range(B):
        bt[b] = perm[b * nb:(b + 1) * nb]
    pool = jax.random.normal(jax.random.fold_in(KEY, 2), (P, pg, Hkv, D), dtype)
    outs = {}
    for impl in ("xla", "pallas"):
        outs[impl] = ops.paged_prefill_write(
            k_new, v_new, positions, jnp.asarray(bt), pool, pool, impl=impl)
    for b in range(B):
        ln = int(lens[b])
        for impl in ("xla", "pallas"):
            kk, vv = outs[impl]
            gk = np.asarray(kk)[bt[b]].reshape(nb * pg, Hkv, D)
            gv = np.asarray(vv)[bt[b]].reshape(nb * pg, Hkv, D)
            # written tokens land at slot == position, bit-exact
            np.testing.assert_array_equal(
                gk[:ln], np.asarray(k_new)[b, T - ln:])
            np.testing.assert_array_equal(
                gv[:ln], np.asarray(v_new)[b, T - ln:])


def test_paged_prefill_write_ref_leaves_unmapped_pages_untouched():
    """The jnp oracle routes pads to the null page and never touches pages
    outside the block tables."""
    from repro.kernels.ref import paged_prefill_write_ref
    B, T, pg, Hkv, D, P = 1, 8, 4, 1, 4, 5
    k_new = jnp.ones((B, T, Hkv, D))
    positions = _positions(B, T, [6])
    bt = jnp.asarray([[2, 3]], jnp.int32)
    pool = jnp.full((P, pg, Hkv, D), 7.0)
    kk, _ = paged_prefill_write_ref(k_new, k_new, positions, bt, pool, pool)
    kk = np.asarray(kk)
    np.testing.assert_array_equal(kk[1], np.full((pg, Hkv, D), 7.0))
    np.testing.assert_array_equal(kk[4], np.full((pg, Hkv, D), 7.0))
    np.testing.assert_array_equal(kk[2], np.ones((pg, Hkv, D)))
    np.testing.assert_array_equal(kk[3, :2], np.ones((2, Hkv, D)))
    # pads hit only the null page
    assert (kk[3, 2:] == 7.0).all()


# ---------------------------------------------------------------------------
# fused RoPE + paged-KV kernels (PR 10)
# ---------------------------------------------------------------------------
def _fused_write_setup(B, T, pg, Hkv, D, dtype=jnp.float32, seed=0,
                       starts=None):
    """Left-padded unrotated prefill K/V + disjoint block tables.  With
    ``starts`` (page-aligned), row b's first ``starts[b]`` slots play
    resident/shared pages whose contents must be preserved."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(1, T + 1, size=B)
    lens[0] = T
    starts = [0] * B if starts is None else list(starts)
    # positions: row b covers absolute slots starts[b] .. starts[b]+len-1
    idx = np.arange(T)[None]
    L = np.asarray(lens)[:, None]
    pos = np.where(idx < T - L, -1,
                   idx - (T - L) + np.asarray(starts)[:, None]).astype(np.int32)
    nb = -(-(T + max(starts)) // pg) + 1
    P = B * nb + 1
    k_new = jax.random.normal(KEY, (B, T, Hkv, D), dtype)
    v_new = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, Hkv, D), dtype)
    bt = np.zeros((B, nb), np.int32)
    perm = rng.permutation(np.arange(1, P))
    for b in range(B):
        bt[b] = perm[b * nb:(b + 1) * nb]
    pool = jax.random.normal(jax.random.fold_in(KEY, 2), (P, pg, Hkv, D), dtype)
    return (k_new, v_new, jnp.asarray(pos), jnp.asarray(bt), pool,
            [int(x) for x in lens], starts, nb)


@pytest.mark.parametrize("B,T,pg,Hkv,D", [
    (1, 16, 8, 1, 8),
    (2, 24, 8, 2, 16),
    (3, 12, 4, 2, 8),   # non-pow2 batch, partial last page
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rope_prefill_write_matches_oracle(B, T, pg, Hkv, D, dtype):
    """ONE Pallas pass == rope-then-write oracle on every observable slot
    (rotated K within atol; V bit-exact — the kernel never touches V math)."""
    k_new, v_new, pos, bt, pool, lens, _, nb = _fused_write_setup(
        B, T, pg, Hkv, D, dtype)
    outs = {impl: ops.fused_rope_prefill_write(k_new, v_new, pos, bt,
                                               pool, pool, impl=impl)
            for impl in ("xla", "pallas")}
    for b in range(B):
        ln = lens[b]
        gk = {i: np.asarray(o[0])[np.asarray(bt)[b]].reshape(nb * pg, Hkv, D)
              for i, o in outs.items()}
        gv = {i: np.asarray(o[1])[np.asarray(bt)[b]].reshape(nb * pg, Hkv, D)
              for i, o in outs.items()}
        np.testing.assert_allclose(
            gk["pallas"][:ln].astype(np.float32),
            gk["xla"][:ln].astype(np.float32), atol=ATOL[dtype])
        np.testing.assert_array_equal(gv["pallas"][:ln], gv["xla"][:ln])


def test_fused_prefill_write_tail_preserves_resident_pages():
    """Shared-prefix tail (page-aligned start > 0): slots below start are
    passed through BIT-EXACT from the aliased pool input; novel slots
    match the oracle."""
    B, T, pg, Hkv, D = 2, 16, 8, 2, 16
    starts = [8, 0]  # row 0 resumes after one resident page
    k_new, v_new, pos, bt, pool, lens, starts, nb = _fused_write_setup(
        B, T, pg, Hkv, D, starts=starts, seed=3)
    kx, vx = ops.fused_rope_prefill_write(k_new, v_new, pos, bt, pool, pool,
                                          impl="xla")
    kp, vp = ops.fused_rope_prefill_write(k_new, v_new, pos, bt, pool, pool,
                                          impl="pallas")
    bt_np = np.asarray(bt)
    for b in range(B):
        st, ln = starts[b], lens[b]
        g = lambda arr: np.asarray(arr)[bt_np[b]].reshape(nb * pg, Hkv, D)
        # resident slots: exactly the pre-existing pool contents
        np.testing.assert_array_equal(g(kp)[:st], np.asarray(pool)[bt_np[b]]
                                      .reshape(nb * pg, Hkv, D)[:st])
        # novel slots: oracle agreement
        np.testing.assert_allclose(g(kp)[st:st + ln], g(kx)[st:st + ln],
                                   atol=2e-5)
        np.testing.assert_array_equal(g(vp)[st:st + ln], g(vx)[st:st + ln])


def test_fused_prefill_write_equals_unfused_two_pass():
    """Fused == apply_rope (jnp) + paged_prefill_write: the fusion changes
    pass count, never math."""
    from repro.models.common import apply_rope
    B, T, pg, Hkv, D = 2, 16, 8, 2, 16
    k_new, v_new, pos, bt, pool, lens, _, nb = _fused_write_setup(
        B, T, pg, Hkv, D, seed=5)
    fused = ops.fused_rope_prefill_write(k_new, v_new, pos, bt, pool, pool,
                                         impl="xla", theta=10000.0)
    k_rot = apply_rope(k_new, jnp.maximum(pos, 0), 10000.0)
    unfused = ops.paged_prefill_write(k_rot, v_new, pos, bt, pool, pool,
                                      impl="xla")
    for b in range(B):
        ln = lens[b]
        for f, u in zip(fused, unfused):
            gf = np.asarray(f)[np.asarray(bt)[b]].reshape(nb * pg, Hkv, D)
            gu = np.asarray(u)[np.asarray(bt)[b]].reshape(nb * pg, Hkv, D)
            np.testing.assert_allclose(gf[:ln], gu[:ln], atol=2e-5)


def _fused_decode_setup(B, nb, pg, Hq, Hkv, D, dtype=jnp.float32, seed=0):
    """Paged pool mid-decode: each row has ``fill`` tokens resident and a
    new token destined for slot ``fill`` (slot_pos already marks it — the
    token must attend to itself)."""
    P = B * nb + 1
    kp = jax.random.normal(KEY, (P, pg, Hkv, D), dtype)
    vp = jax.random.normal(jax.random.fold_in(KEY, 1), (P, pg, Hkv, D), dtype)
    rng = np.random.default_rng(seed)
    bt = np.zeros((B, nb), np.int32)
    slot_pos = np.full((B, nb * pg), -1, np.int32)
    slots = []
    # pages of different rows must be DISJOINT (allocator contract — the
    # fused kernel's aliased tile writes rely on it; only null page 0 is
    # shared, and only by unmapped blocks)
    perm = rng.permutation(np.arange(1, P))
    for b in range(B):
        fill = int(rng.integers(0, nb * pg))  # new token lands at slot fill
        n_used = -(-(fill + 1) // pg)
        bt[b, :n_used] = perm[b * nb:b * nb + n_used]
        slot_pos[b, :fill + 1] = np.arange(fill + 1)
        slots.append(fill)
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Hq, D), dtype)
    kn = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Hkv, D), dtype)
    vn = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Hkv, D), dtype)
    s = jnp.asarray(slots, jnp.int32)
    return (q, kn, vn, jnp.asarray(bt), jnp.asarray(slot_pos), s, s, kp, vp)


@pytest.mark.parametrize("B,nb,pg,Hq,Hkv,D", [
    (1, 2, 8, 1, 1, 8),
    (2, 3, 8, 4, 2, 16),
    (4, 2, 8, 4, 1, 32),   # MQA
    (3, 3, 8, 6, 2, 16),   # non-pow2 batch
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 6])
def test_fused_rope_decode_append_matches_oracle(B, nb, pg, Hq, Hkv, D,
                                                 dtype, window):
    args = _fused_decode_setup(B, nb, pg, Hq, Hkv, D, dtype)
    ox, kx, vx = ops.fused_rope_decode_append(*args, window=window,
                                              impl="xla")
    op_, kp_, vp_ = ops.fused_rope_decode_append(*args, window=window,
                                                 impl="pallas")
    np.testing.assert_allclose(np.asarray(op_.astype(jnp.float32)),
                               np.asarray(ox.astype(jnp.float32)),
                               atol=ATOL[dtype])
    # the appended token's K/V landed identically in the pool
    bt, slots = np.asarray(args[3]), np.asarray(args[5])
    for b in range(B):
        s = int(slots[b])
        page, off = int(bt[b, s // pg]), s % pg
        np.testing.assert_allclose(
            np.asarray(kp_)[page, off].astype(np.float32),
            np.asarray(kx)[page, off].astype(np.float32), atol=ATOL[dtype])
        np.testing.assert_array_equal(np.asarray(vp_)[page, off],
                                      np.asarray(vx)[page, off])


def test_fused_decode_append_equals_unfused_three_pass():
    """Fused == rope (jnp) + XLA scatter + paged_decode_attention: the
    single launch reproduces the three-pass pipeline's math."""
    from repro.models.common import apply_rope
    B, nb, pg, Hq, Hkv, D = 2, 3, 8, 4, 2, 16
    q, kn, vn, bt, slot_pos, slots, q_pos, kp, vp = _fused_decode_setup(
        B, nb, pg, Hq, Hkv, D, seed=4)
    fo, fk, fv = ops.fused_rope_decode_append(q, kn, vn, bt, slot_pos, slots,
                                              q_pos, kp, vp, impl="xla")
    qr = apply_rope(q[:, None], q_pos[:, None], 10000.0)[:, 0]
    kr = apply_rope(kn[:, None], q_pos[:, None], 10000.0)[:, 0]
    pages = bt[jnp.arange(B), slots // pg]
    uk = kp.at[pages, slots % pg].set(kr)
    uv = vp.at[pages, slots % pg].set(vn)
    uo = ops.paged_decode_attention(qr, uk, uv, bt, slot_pos, q_pos,
                                    impl="xla")
    np.testing.assert_allclose(np.asarray(fo), np.asarray(uo), atol=2e-5)
    np.testing.assert_allclose(np.asarray(fk), np.asarray(uk), atol=2e-5)
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(uv))
