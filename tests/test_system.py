"""End-to-end system tests: the full SCLS stack — profile a real JAX engine,
fit the estimator, DP-batch, max-min offload, serve on real engines with
virtual-time workers — plus the dry-run/sharding machinery in a subprocess
(which needs its own XLA device-count flag; never set it in this process).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.realtime import RealCluster
from repro.cluster.trace import WorkloadSpec, generate_trace
from repro.configs import get_config
from repro.core.memory import AnalyticMemoryEstimator
from repro.core.schedulers import make_strategy
from repro.engine.profiler import fit_estimator
from repro.engine.static_engine import StaticEngine
from repro.models.registry import get_model

TINY = WorkloadSpec("tiny", input_mu=3.0, input_sigma=0.6, gen_mu=2.2,
                    gen_sigma=0.6, max_input=48, max_gen=24)


@pytest.fixture(scope="module")
def served_cluster():
    cfg = get_config("llama3.2-1b", reduced=True)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2, 4),
                              input_lens=(16, 32), n_decode_iters=2, repeats=1)
    mem = AnalyticMemoryEstimator(delta_bytes=model.kv_bytes_per_token(),
                                  m_available=64e6, zeta=0.9, bucket=8)
    trace = generate_trace(2.0, 15.0, TINY, seed=5, vocab_size=cfg.vocab_size)
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8)
               for _ in range(2)]
    strategy = make_strategy("scls", slice_len=8, max_gen=24, gamma=0.25)
    cluster = RealCluster(strategy, engines, est, mem)
    metrics = cluster.run(trace, 15.0)
    return cfg, model, params, trace, metrics, cluster


def test_e2e_all_requests_served_with_real_tokens(served_cluster):
    cfg, model, params, trace, metrics, cluster = served_cluster
    assert metrics.n_completed == metrics.n_requests == len(trace)
    for r in trace:
        assert r.done and len(r.output_tokens) == min(r.gen_len, r.max_gen)


def test_e2e_output_tokens_match_oneshot_generation(served_cluster):
    """Tokens produced through slicing + rescheduling + batching must equal
    direct one-shot generation of each request (greedy determinism)."""
    cfg, model, params, trace, metrics, cluster = served_cluster
    eng = StaticEngine(model, params, eos_id=1, len_bucket=8)
    for r in list(trace)[:5]:
        want = eng.serve_batch([r.prompt], slice_len=32,
                               forced_gen_lens=[min(r.gen_len, r.max_gen)]
                               ).results[0]["tokens"]
        assert r.output_tokens == want, f"rid={r.rid}"


def test_e2e_metrics_sane(served_cluster):
    _, _, _, _, m, _ = served_cluster
    assert m.throughput > 0
    assert m.avg_batch_size >= 1
    assert 1.0 <= m.avg_schedules <= 4.0


DRYRUN_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
from repro.configs import get_config
from repro.configs.shapes import InputShape, token_specs
from repro.launch import sharding as shr
from repro.launch.steps import make_train_step
from repro.models.registry import get_model
from repro.optim.adamw import AdamWConfig, init_adamw

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = get_config("llama3.2-1b", reduced=True)
model = get_model(cfg)
params_t = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
params_ns = shr.named(shr.tree_pspecs(params_t, mesh, cfg), mesh)
opt_t = jax.eval_shape(init_adamw, params_t)
opt_ns = shr.named(shr.tree_pspecs(opt_t, mesh, cfg), mesh)
shape = InputShape("t", 64, 8, "train")
batch_t = token_specs(cfg, shape)
batch_ns = shr.named(shr.batch_pspec(batch_t, mesh, 8), mesh)
step = make_train_step(model, AdamWConfig())
with mesh:
    lowered = jax.jit(step, in_shardings=(params_ns, opt_ns, batch_ns),
                      out_shardings=(params_ns, opt_ns, None)).lower(
        params_t, opt_t, batch_t)
    compiled = lowered.compile()
cost = compiled.cost_analysis()
if isinstance(cost, list):
    cost = cost[0]
from repro.launch.hlo_analysis import parse_collectives
colls = parse_collectives(compiled.as_text())
print(json.dumps({"flops": cost.get("flops", 0),
                  "collectives": sorted(colls)}))
"""


def test_dryrun_multipod_sharding_in_subprocess():
    """An 8-device (2,2,2) pod/data/model mesh must lower+compile a real
    sharded train step, and grads must cross pods (collectives present)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    r = subprocess.run([sys.executable, "-c", DRYRUN_SNIPPET], env=env,
                       capture_output=True, text=True, timeout=540, cwd=root)
    assert r.returncode == 0, r.stderr[-4000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["flops"] > 0
    assert ("all-reduce" in out["collectives"]
            or "reduce-scatter" in out["collectives"])


def test_collective_parser():
    from repro.launch.hlo_analysis import parse_collectives
    hlo = """
  %ag = bf16[2,16,128]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(%y), to_apply=%add
  %ag2 = bf16[4,4]{1,0} all-gather-start(%z)
  %ag2d = bf16[4,4]{1,0} all-gather-done(%ag2)
"""
    c = parse_collectives(hlo)
    assert c["all-gather"][0] == 2  # start counted once, done skipped
    assert c["all-gather"][1] == 2 * 16 * 128 * 2 + 4 * 4 * 2
    assert c["all-reduce"] == (1, 128 * 4)
