"""GOOD fixture: the three accepted shapes — paired release on all
paths, the blessed conditional-cleanup ``finally``, and an annotated
ownership transfer."""


def paired(alloc, rid, n):
    pages = alloc.reserve(rid, n)
    try:
        process(pages)
    finally:
        alloc.release(rid)


def conditional_finally(alloc, slots, rid, n):
    # the canonical unwind loop: the finally releases exactly the
    # residual owner set, which the dataflow cannot prove — blessed
    try:
        alloc.reserve(rid, n)
        run(slots)
    finally:
        for s in slots:
            if s.owner >= 0:
                alloc.release(s.owner)


def transfer(alloc, rid, n):
    return alloc.reserve(rid, n)  # repro: transfer(allocator-pairing) — caller releases


def unrelated_list_extend(pool, items):
    pool.extend(items)  # list method, not an allocator: never matched
