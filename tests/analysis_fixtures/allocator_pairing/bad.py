"""BAD fixture: the PR 3 cancel-path shape — acquires that can exit
without a release and carry no ownership-transfer annotation."""


def cancel_request(alloc, rid, n, active):
    pages = alloc.reserve(rid, n)          # line 6: leaks on both exits
    if rid not in active:
        return None                        # cancel path: never released
    return pages


def risky_extend(allocator, rid, n):
    allocator.extend(rid, n)               # line 13: leaks on exception
    validate(rid)                          # may raise before the release
    allocator.release(rid)
