"""BAD fixture: unannotated public surface."""


def loose(a, b=3):                         # line 4: params + return missing
    return a + b


class Thing:
    def __init__(self, size, dtype) -> None:   # line 9: params missing
        self.size = size
        self.dtype = dtype

    def run(self, x: int):                 # line 13: return missing
        return x * self.size
