"""GOOD fixture: fully annotated, plus the accepted exemptions."""
from typing import Any


def tight(a: int, b: int = 3) -> int:
    return a + b


class Thing:
    def __init__(self, size: int, dtype: Any) -> None:  # __init__: no return
        self.size = size
        self.dtype = dtype

    def close(self, *exc: object) -> None:  # annotated vararg
        pass

    def legacy(self, blob):  # repro: allow(api-typing) — accepted exception
        return blob
