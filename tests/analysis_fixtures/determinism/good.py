"""GOOD fixture: the deterministic spellings of the same code."""
import numpy as np


def schedule(reqs, now):
    rng = np.random.default_rng(0)         # seeded ctor: allowed
    noise = rng.uniform()                  # instance method: allowed
    reqs.sort(key=lambda r: r.rid)
    pending = {r.rid for r in reqs}
    for rid in sorted(pending):            # sorted(): order pinned
        touch(rid, now, noise)
    return min(pending)
