"""BAD fixture: every construct the determinism rule bans."""
import random
import time

import numpy as np


def schedule(reqs):
    t = time.time()                        # line 9: wall clock
    random.shuffle(reqs)                   # line 10: global stdlib RNG
    noise = np.random.uniform()            # line 11: global numpy RNG
    rng = np.random.default_rng()          # line 12: seedless ctor
    reqs.sort(key=lambda r: id(r))         # line 13: id() ordering
    pending = {r.rid for r in reqs}
    for rid in pending:                    # line 15: unordered-set iteration
        touch(rid, t, noise, rng)
    return pending.pop()                   # line 17: arbitrary element
