"""BAD fixture: hook calls outside their ``.enabled`` guard."""


class Engine:
    def step(self):
        self.obs.on_step(1)                # line 6: no guard at all

    def finish(self):
        if self.obs.enabled:
            self.obs.on_finish()
        self.obs.on_late()                 # line 11: outside the guard

    def wrong_chain(self):
        if self.obs.enabled:
            self.core.obs.on_other()       # line 15: guard checks self.obs
