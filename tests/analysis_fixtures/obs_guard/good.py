"""GOOD fixture: every guarded form the pass accepts."""


class Engine:
    def block_guard(self):
        if self.obs.enabled:
            self.obs.on_step(1)

    def early_exit_guard(self):
        if not self.obs.enabled:
            return
        self.obs.on_step(2)

    def and_guard(self):
        if self.obs.enabled and self.ready:
            self.obs.on_ready()

    def other_chain(self):
        if self.core.obs.enabled:
            self.core.obs.on_admission()

    def loop_inside_guard(self):
        if self.obs.enabled:
            for r in self.batch:
                self.obs.on_request(r)

    def not_a_hook(self):
        self.scheduler.on_tick()  # receiver is not an .obs chain
