"""Oracle module for the bad fixture — deliberately missing
``badkernel_ref``."""
