"""Dispatch module for the bad fixture — deliberately never imports
the kernel, so the xla/pallas impl switch does not cover it."""
