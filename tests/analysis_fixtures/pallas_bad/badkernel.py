"""BAD fixture kernel: no oracle, no dispatch, mutable index-map
closure, out-of-range aliases, Python branching on a traced ref."""
import jax
import jax.experimental.pallas as pl


def badkernel(x, y):
    shapes = [x.shape[0]]                  # mutable local ...
    return pl.pallas_call(
        _impl,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec((8,), lambda i: (shapes[0],))],  # ... closed over
        input_output_aliases={5: 0, 0: 3},  # key 5 / value 3 out of range
    )(x, y)


def _impl(x_ref, o_ref):
    v = x_ref[0]
    if v > 0:                              # Python branch on traced value
        o_ref[0] = v
