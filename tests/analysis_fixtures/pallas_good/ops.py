"""Dispatch module for the good fixture (the import is what the pass
checks; this module is never executed)."""
from repro.kernels.goodkernel import goodkernel  # noqa: F401
