"""Oracle module for the good fixture."""
import jax.numpy as jnp


def goodkernel_ref(x):
    return jnp.where(x > 0, x, 0)
