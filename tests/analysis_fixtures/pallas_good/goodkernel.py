"""GOOD fixture kernel: oracle + dispatch declared, immutable index-map
closure, in-range aliases, ``@pl.when`` instead of Python branching."""
import jax
import jax.experimental.pallas as pl


def goodkernel(x):
    block = x.shape[0]                     # int local: fine to close over
    return pl.pallas_call(
        _impl,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        input_output_aliases={0: 0},
    )(x)


def _impl(x_ref, o_ref):
    v = x_ref[0]

    @pl.when(v > 0)
    def _():
        o_ref[0] = v
