"""Multi-turn sessions + COW prefix sharing (PR 7), cross-layer:
Session/aio composition, golden-placement equivalence with the sharing
machinery armed, token exactness through the engine and the HTTP chat
endpoint, and allocator hygiene on cancel/close."""
import asyncio
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.serving import ServingConfig, default_sim_environment

POOL_TOKENS = 512
PAGE_TOKENS = 8


@pytest.fixture(scope="module")
def sim_env():
    return default_sim_environment("hf")


@pytest.fixture(scope="module")
def real_env():
    import jax
    from repro.configs import get_config
    from repro.engine.profiler import fit_estimator
    from repro.models.registry import get_model
    arch = get_config("llama3.2-1b", reduced=True)
    model = get_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    est, _, _ = fit_estimator(model, params, batch_sizes=(1, 2),
                              input_lens=(16, 32), n_decode_iters=2, repeats=1)
    return arch, model, params, est


def _real_server(model, est, params, prefix_sharing=True, workers=1):
    from repro.engine.static_engine import StaticEngine
    cfg = ServingConfig(strategy="scls", backend="real", workers=workers,
                        kv_layout="paged", kv_retain="request",
                        page_tokens=PAGE_TOKENS, slice_len=8, max_gen=8,
                        gamma=0.25, mem_bucket=8,
                        prefix_sharing=prefix_sharing)
    delta = model.kv_bytes_per_token()
    pool_pages = POOL_TOKENS // PAGE_TOKENS
    mem = cfg.memory_estimator(
        delta, m_available=pool_pages * PAGE_TOKENS * delta / cfg.zeta + 1)
    assert mem.total_blocks == pool_pages
    engines = [StaticEngine(model, params, eos_id=1, len_bucket=8,
                            kv_layout="paged", page_tokens=PAGE_TOKENS,
                            kv_pool_tokens=POOL_TOKENS,
                            prefix_sharing=prefix_sharing)
               for _ in range(workers)]
    return cfg.build_real(engines, est, mem)


# ---------------------------------------------------------------------------
# golden-equivalence guard: the sharing machinery must not move a single
# batch on the sim goldens (no shareable prefixes exist there)
# ---------------------------------------------------------------------------
def test_golden_dispatch_bit_exact_with_affinity_hook_armed():
    """PR 3's golden dispatch log is reproduced bit-for-bit with the PR 7
    retention-affinity hook *armed* (``affinity_fn`` set, returning None
    for every batch — the sim backend's truthful answer: nothing resident)
    and full observability on: placement is untouched and no
    ``prefix_share`` audit records appear."""
    import copy
    import os
    from repro.cluster.simulator import ClusterSimulator
    from repro.cluster.trace import CODEFUSE, generate_trace
    from repro.core.estimator import a100_llama13b_profile
    from repro.core.memory import (A100_80GB_AVAILABLE,
                                   AnalyticMemoryEstimator, LLAMA2_13B_DELTA)
    from repro.core.schedulers import make_strategy
    from repro.obs import Observability
    from repro.serving import fitted_estimator
    with open(os.path.join(os.path.dirname(__file__), "data",
                           "golden_batch_compositions.json")) as f:
        g = json.load(f)
    args = g["scenario_args"]
    want = next(r for r in g["runs"]
                if r["strategy"] == "scls" and r["noise_sigma"] == 0.05)
    true_lat = a100_llama13b_profile()
    est = fitted_estimator(true_lat, seed=0)
    mem = AnalyticMemoryEstimator(delta_bytes=LLAMA2_13B_DELTA,
                                  m_available=A100_80GB_AVAILABLE, zeta=0.9)
    trace = generate_trace(args["rate"], args["duration"], CODEFUSE,
                           seed=args["trace_seed"])
    s = make_strategy("scls", slice_len=args["slice_len"],
                      fixed_batch_size=args["fixed_batch_size"],
                      gamma=args["gamma"], max_parallel=args["max_parallel"])
    sim = ClusterSimulator(s, args["workers"], true_lat, est, mem,
                           noise_sigma=want["noise_sigma"],
                           seed=args["sim_seed"])
    sim.core.obs = Observability.standard()
    sim.core.obs.attach(sim.core)
    calls = []

    def affinity(batch):
        calls.append(len(batch.requests))
        return None  # nothing resident on a sim backend, ever

    sim.core.offloader.affinity_fn = affinity
    res = sim.run(copy.deepcopy(trace), args["duration"])
    assert res.metrics.n_completed == want["n_completed"]
    assert sim.batch_log == want["batch_log"]          # bit-exact placement
    assert calls, "the armed hook was never consulted"
    assert sim.core.obs.audit.query(kind="prefix_share") == []
    assert res.metrics.prefix_hit_tokens == 0
    assert res.metrics.shared_blocks == 0


# ---------------------------------------------------------------------------
# Session composition on the sim backend
# ---------------------------------------------------------------------------
def test_session_sim_accumulates_history_and_survives_mid_flight_turn(sim_env):
    true_lat, est, mem = sim_env
    cfg = ServingConfig(strategy="scls", workers=2, max_gen=32)

    async def main():
        server = cfg.build_sim(true_lat, est, mem).aio
        async with server:
            async with server.session(max_gen=8) as s:
                h1 = await s.submit_turn(input_len=10, gen_len=5)
                await h1.result()
                # history folds in lazily, at the *next* submit_turn
                assert s.history_len == 0
                h2 = await s.submit_turn(input_len=4, gen_len=3)
                assert s.history_len == 15             # 10 prompt + 5 out
                # turn 3 while turn 2 is still in flight: submit_turn
                # awaits it internally before composing the prompt
                h3 = await s.submit_turn(input_len=6, gen_len=2)
                r3 = await h3.result()
            assert h2.request.input_len == 10 + 5 + 4
            assert h3.request.input_len == 19 + 3 + 6
            assert r3.session_id == h2.request.session_id == s.session_id
            assert s.n_turns == 3
            with pytest.raises(RuntimeError):
                await s.submit_turn(input_len=1)       # closed
            m = await server.close()
        return m

    m = asyncio.run(main())
    assert m.n_completed == 3
    assert m.prefix_hit_tokens == 0                    # sim: no KV to share


def test_session_sim_cancelled_turn_leaves_history_untouched(sim_env):
    true_lat, est, mem = sim_env
    cfg = ServingConfig(strategy="scls", workers=1, max_gen=64)

    async def main():
        server = cfg.build_sim(true_lat, est, mem).aio
        async with server:
            s = server.session()
            h1 = await s.submit_turn(input_len=8, gen_len=4)
            await h1.result()
            h2 = await s.submit_turn(input_len=100, gen_len=50)
            h2.cancel()
            await h2.result()
            assert h2.cancelled
            h3 = await s.submit_turn(input_len=5, gen_len=2)
            await h3.result()
            await s.close()
            m = await server.close()
        return h3, m

    h3, m = asyncio.run(main())
    # the cancelled turn contributed nothing: turn 3 = turn-1 history + 5
    assert h3.request.input_len == 8 + 4 + 5


# ---------------------------------------------------------------------------
# real backend: cross-layer token exactness + allocator hygiene
# ---------------------------------------------------------------------------
def _turn_prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, size=n).astype(np.int32)
            for n in (24, 12, 9)]


def test_real_session_three_turns_token_exact_and_shares(real_env):
    """Satellite acceptance: a 3-turn Session on the retain-mode paged
    backend produces the exact token stream of (a) the same turns with
    sharing disabled and (b) a single-shot submission of the concatenated
    final prompt — while actually serving the history from shared pages
    (prefix_hit_tokens > 0) and draining back to the page baseline."""
    arch, model, params, est = real_env
    turns = _turn_prompts(arch.vocab_size)

    async def run_session(prefix_sharing):
        server = _real_server(model, est, params, prefix_sharing).aio
        alloc = server.core.backend.allocators[0]
        baseline = alloc.free_blocks
        outs, final_prompt = [], None
        async with server:
            s = server.session(max_gen=6)
            for t in turns:
                h = await s.submit_turn(t, gen_len=4)
                await h.result()
                outs.append(list(h.output_tokens))
            final_prompt = np.asarray(h.request.prompt)
            await s.close()
            assert alloc.free_blocks == baseline       # anchor dropped
            assert not alloc.owners()
            m = await server.close()
        return outs, final_prompt, m

    async def run_single(prompt):
        server = _real_server(model, est, params, True).aio
        async with server:
            h = server.submit(prompt, gen_len=4, max_gen=6)
            await h.result()
            out = list(h.output_tokens)
            await server.close()
        return out

    outs_on, prompt_on, m_on = asyncio.run(run_session(True))
    outs_off, prompt_off, m_off = asyncio.run(run_session(False))
    assert outs_on == outs_off                         # sharing is invisible
    np.testing.assert_array_equal(prompt_on, prompt_off)
    assert m_on.prefix_hit_tokens > 0                  # ...but real
    assert m_on.shared_blocks > 0
    assert m_on.reprefill_tokens == 0
    assert m_off.prefix_hit_tokens == 0
    # single-shot of the concatenated conversation == turn 3
    assert asyncio.run(run_single(prompt_on)) == outs_on[2]


def test_real_session_turn_submitted_mid_slice_is_exact(real_env):
    """A turn submitted while the previous one is mid-slice must neither
    corrupt history nor change tokens: submit_turn awaits the in-flight
    turn, and the joined prefix serves the same stream."""
    arch, model, params, est = real_env
    turns = _turn_prompts(arch.vocab_size, seed=1)

    async def main():
        server = _real_server(model, est, params, True).aio
        async with server:
            s = server.session(max_gen=6)
            h1 = await s.submit_turn(turns[0], gen_len=4)
            # do NOT await h1: turn 2 goes in while turn 1 is in flight
            h2 = await s.submit_turn(turns[1], gen_len=4)
            await h2.result()
            assert h1.done
            expected = np.concatenate(
                [turns[0], np.asarray(h1.output_tokens, np.int32), turns[1]])
            np.testing.assert_array_equal(np.asarray(h2.request.prompt),
                                          expected)
            out2 = list(h2.output_tokens)
            await s.close()
            m = await server.close()
        return out2, m

    out2, m = asyncio.run(main())
    assert len(out2) == 4
    assert m.prefix_hit_tokens > 0


def test_real_session_cancel_mid_conversation_restores_baseline(real_env):
    """Cancel (and EOS) mid-conversation: the cancelled turn's envelope,
    the anchored prefix pages, and every shared reference all drain back
    to the allocator's free-block baseline on close."""
    arch, model, params, est = real_env
    turns = _turn_prompts(arch.vocab_size, seed=2)

    async def main():
        server = _real_server(model, est, params, True).aio
        alloc = server.core.backend.allocators[0]
        baseline = alloc.free_blocks
        async with server:
            s = server.session(max_gen=8)
            h1 = await s.submit_turn(turns[0], gen_len=6)
            await h1.result()
            h2 = await s.submit_turn(turns[1], gen_len=8)
            h2.cancel()
            await h2.result()
            assert h2.cancelled
            # the anchor still holds turn 1's pages (session is alive)
            assert alloc.used_blocks > 0
            h3 = await s.submit_turn(turns[2], gen_len=2)
            await h3.result()
            # cancelled turn absent from history
            assert h3.request.input_len == len(turns[0]) + 6 + len(turns[2])
            await s.close()
            assert alloc.free_blocks == baseline
            assert not alloc.owners()
            await server.close()

    asyncio.run(main())


def test_real_affinity_keeps_turns_on_anchor_worker(real_env):
    """Regression for the MaxMin retention-affinity tiebreak: with two
    workers and the Eq. 11 minimum nudged *away* from the anchor worker,
    the armed affinity hook keeps the next turn where its prefix pages
    live (prefix hit, no re-prefill of history) while the plain policy
    moves it and pays the full prefill — with identical tokens either
    way, and the load imbalance the override tolerates bounded by
    epsilon * est_time."""
    arch, model, params, est = real_env
    turns = _turn_prompts(arch.vocab_size, seed=3)

    async def run(affinity):
        server = _real_server(model, est, params, True, workers=2).aio
        async with server:
            off = server.core.offloader
            assert off.affinity_fn is not None         # wired by the core
            if not affinity:
                off.affinity_fn = None
            s = server.session(max_gen=6)
            h1 = await s.submit_turn(turns[0], gen_len=4)
            await h1.result()
            anchor_wid, _ = server.core.backend._session_anchor[s.session_id]
            # nudge: the other worker becomes the Eq. 11 minimum, so a
            # residency-blind placement moves turn 2 off the anchor
            off.loads = {w: (0.005 if w == anchor_wid else 0.0)
                         for w in off.loads}
            h2 = await s.submit_turn(turns[1], gen_len=4)
            await h2.result()
            outs = (list(h1.output_tokens), list(h2.output_tokens))
            await s.close()
            m = await server.close()
        return outs, m

    outs_on, m_on = asyncio.run(run(True))
    outs_off, m_off = asyncio.run(run(False))
    assert outs_on == outs_off                         # placement-invariant
    assert m_on.prefix_hit_tokens > 0                  # stayed on the anchor
    assert m_off.prefix_hit_tokens == 0                # moved: full prefill


# ---------------------------------------------------------------------------
# HTTP chat endpoint
# ---------------------------------------------------------------------------
def _post(url, path, body):
    req = urllib.request.Request(url + path, json.dumps(body).encode(),
                                 {"Content-Type": "application/json"})
    return json.loads(urllib.request.urlopen(req).read())


def test_http_chat_completions_sim(sim_env):
    from repro.serving import HTTPFrontend
    true_lat, est, mem = sim_env
    server = ServingConfig(strategy="scls", workers=2, max_gen=16,
                           slice_len=8).build_sim(true_lat, est, mem)
    with HTTPFrontend(server.aio, vocab_size=512) as front:
        msgs = [{"role": "user", "content": "hello there"}]
        r = _post(front.url, "/v1/chat/completions",
                  dict(messages=msgs, max_tokens=6, session=7))
        assert r["object"] == "chat.completion"
        assert r["choices"][0]["message"]["role"] == "assistant"
        assert r["choices"][0]["finish_reason"] in ("stop", "length")
        assert r["session"] == 7
        assert r["usage"]["completion_tokens"] > 0
        # streaming: chat.completion.chunk frames, terminated by [DONE]
        req = urllib.request.Request(
            front.url + "/v1/chat/completions",
            json.dumps(dict(messages=msgs, max_tokens=4,
                            stream=True)).encode(),
            {"Content-Type": "application/json"})
        lines = [ln for ln in
                 urllib.request.urlopen(req).read().decode().splitlines()
                 if ln.startswith("data: ")]
        assert lines[-1] == "data: [DONE]"
        first = json.loads(lines[0][len("data: "):])
        assert first["object"] == "chat.completion.chunk"
        assert "content" in first["choices"][0]["delta"]
        # session release is an explicit DELETE
        dreq = urllib.request.Request(front.url + "/v1/sessions/7",
                                      method="DELETE")
        assert json.loads(urllib.request.urlopen(dreq).read())["released"]
        # malformed requests are 400s, not 500s
        for body in (dict(messages=[]),
                     dict(messages=[{"role": "user"}]),
                     dict(messages=msgs, session=0),
                     dict(messages=msgs, max_tokens=0)):
            with pytest.raises(urllib.error.HTTPError) as ei:
                _post(front.url, "/v1/chat/completions", body)
            assert ei.value.code == 400


def test_http_chat_multi_turn_real_token_exact(real_env):
    """3 chat turns over HTTP with the ``session`` extension == one-shot
    POST of the full message list: identical assistant text, and the
    server-side metrics show the history was served from shared pages."""
    from repro.serving import HTTPFrontend
    arch, model, params, est = real_env
    server = _real_server(model, est, params, True)
    with HTTPFrontend(server.aio, vocab_size=arch.vocab_size) as front:
        msgs = []
        replies = []
        for content in ("alpha bravo charlie", "delta echo", "foxtrot"):
            msgs.append({"role": "user", "content": content})
            r = _post(front.url, "/v1/chat/completions",
                      dict(messages=msgs, max_tokens=4, session=1))
            reply = r["choices"][0]["message"]["content"]
            replies.append(reply)
            msgs.append({"role": "assistant", "content": reply})
        # one-shot replay of the whole conversation, no session
        oneshot = _post(front.url, "/v1/chat/completions",
                        dict(messages=msgs[:-1], max_tokens=4))
        assert oneshot["choices"][0]["message"]["content"] == replies[-1]
        m = json.loads(urllib.request.urlopen(
            front.url + "/metrics.json").read())
        assert m["prefix_hit_tokens"] > 0
        assert m["n_completed"] == 4
        dreq = urllib.request.Request(front.url + "/v1/sessions/1",
                                      method="DELETE")
        urllib.request.urlopen(dreq)
        alloc = server.core.backend.allocators[0]
        assert not alloc.owners()


def test_chat_tokenizer_round_trip_and_template_prefix_stability():
    from repro.serving.tokenizer import (ByteTokenizer, HashTokenizer,
                                         for_vocab, render_chat)
    bt = for_vocab(512)
    assert isinstance(bt, ByteTokenizer) and bt.invertible
    text = "hello été"                       # multi-byte UTF-8
    assert bt.decode(bt.encode(text)) == text
    assert min(bt.encode(text)) >= 2                   # never pad/EOS ids
    # reserved + out-of-range ids carry no text
    assert bt.decode([0, 1, 300] + bt.encode("ok")) == "ok"
    ht = for_vocab(64)
    assert isinstance(ht, HashTokenizer) and not ht.invertible
    assert ht.encode("a b") == ht.encode("a  b")       # stable
    assert for_vocab(0) is None
    with pytest.raises(ValueError):
        ByteTokenizer(100)
    # appending a message extends the rendered prompt character-for-
    # character (the prefix-sharing contract)
    msgs = [{"role": "user", "content": "hi"}]
    r1 = render_chat(msgs)
    msgs += [{"role": "assistant", "content": "yo"},
             {"role": "user", "content": "more"}]
    r2 = render_chat(msgs)
    assert r2.startswith(r1[:-len("<|assistant|>\n")])
    assert r2.startswith(render_chat(msgs[:2], add_generation_prompt=False))
    with pytest.raises(ValueError):
        render_chat([{"role": "", "content": "x"}])
    with pytest.raises(ValueError):
        render_chat([{"role": "user", "content": 3}])
