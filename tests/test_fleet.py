"""repro.fleet: instance registry, placement policies, and the fleet
router — lifecycle (join/drain/crash-evict), SSE passthrough, session
pinning with migration accounting, and placement determinism."""
import http.client
import json

import pytest

from repro.fleet import (FleetRouter, InstanceRegistry, InstanceSnapshot,
                         LeastLoadPlacer, PlacementRequest,
                         RetentionAffinityPlacer, RoundRobinPlacer,
                         imbalance, make_placer)
from repro.serving import HTTPFrontend, ServingConfig

SLICE = 8


# ---------------------------------------------------------------------------
# placement policies over synthetic snapshots (no HTTP)
# ---------------------------------------------------------------------------
def snap(url, delay=0.0, **kw):
    return InstanceSnapshot(instance=url, healthy=True, polled_at=0.0,
                            queue_delay_est=delay, **kw)


def preq(rid, inp=8, gen=16, session=None, pinned=None, history=0):
    return PlacementRequest(rid=rid, input_tokens=inp, max_tokens=gen,
                            session_id=session, pinned=pinned,
                            history_tokens=history)


def test_round_robin_cycles_sorted_candidates():
    p = RoundRobinPlacer()
    cands = [snap("http://a"), snap("http://b"), snap("http://c")]
    picks = [p.place(cands, preq(i)).instance for i in range(6)]
    assert picks == ["http://a", "http://b", "http://c"] * 2


def test_least_load_prefers_idle_and_decays_charges():
    p = LeastLoadPlacer(token_time=0.01)
    cands = [snap("http://a", delay=5.0), snap("http://b", delay=0.0)]
    r = preq(1, inp=100, gen=100)         # 2.0 s estimated cost
    assert p.place(cands, r).instance == "http://b"
    # charge accumulates: after two placements b carries 4.0 s > a's 5?
    assert p.place(cands, preq(2, inp=100, gen=100)).instance == "http://b"
    # now b carries 4.0 s of charges; one more 2.0 s request still fits
    # under a's 5.0 s poll, the next tips the balance to a
    assert p.place(cands, preq(3, inp=100, gen=100)).instance == "http://b"
    assert p.place(cands, preq(4, inp=100, gen=100)).instance == "http://a"
    # completion subtracts the estimate back out (Offloader mirror)
    p.on_complete("http://b", r)
    p.on_complete("http://b", r)
    p.on_complete("http://b", r)
    assert p.place(cands, preq(5, inp=100, gen=100)).instance == "http://b"
    # polls do NOT reset the ledger (charges persist until completion,
    # like Offloader loads) — they only prune departed instances
    p.observe(cands)
    assert p._charges["http://b"] > 0.0
    p.observe([snap("http://a", delay=5.0)])   # b evicted/drained
    assert "http://b" not in p._charges


def test_retention_affinity_pins_within_epsilon():
    p = RetentionAffinityPlacer(token_time=0.01, epsilon=0.5)
    cands = [snap("http://a", delay=0.4), snap("http://b", delay=0.0)]
    # session pinned on the busier a; slack = 0.5*(1.0 + 0.6) = 0.8 > gap
    got = p.place(cands, preq(1, inp=50, gen=50, session=9,
                              pinned="http://a", history=60))
    assert got.instance == "http://a"


def test_retention_affinity_migrates_when_pin_overloaded():
    p = RetentionAffinityPlacer(token_time=0.01, epsilon=0.25)
    cands = [snap("http://a", delay=9.0), snap("http://b", delay=0.0)]
    # slack = 0.25*(1.0 + 0.6) = 0.4 << 9.0 gap: the move pays off even
    # after re-prefilling the 60-token history
    got = p.place(cands, preq(1, inp=50, gen=50, session=9,
                              pinned="http://a", history=60))
    assert got.instance == "http://b"


def test_retention_affinity_ignores_missing_pin():
    p = RetentionAffinityPlacer()
    cands = [snap("http://a"), snap("http://b")]
    got = p.place(cands, preq(1, session=3, pinned="http://gone",
                              history=100))
    assert got.instance in ("http://a", "http://b")


def test_placement_deterministic_under_seeded_registry():
    """Same snapshots + same request sequence => identical placements
    (the registry holds no RNG and iterates sorted; pinned here)."""
    def run():
        reg = InstanceRegistry(
            ("http://a", "http://c", "http://b"),
            fetch=lambda url: {"status": "ok", "queue_delay_est":
                               {"http://a": 1.0, "http://b": 0.3,
                                "http://c": 0.7}[url]})
        reg.poll_once()
        p = make_placer("retention_affinity", token_time=0.02)
        seq = []
        for i in range(12):
            session = (i % 3) + 1 if i % 2 else None
            pin = seq[-3][1] if session and len(seq) >= 3 else None
            got = p.place(reg.placeable(),
                          preq(i, inp=4 * i + 1, gen=8 * (i % 4 + 1),
                               session=session, pinned=pin,
                               history=16 * i))
            seq.append((i, got.instance))
        return seq

    a, b = run(), run()
    assert a == b
    assert [u for _, u in a][0] == "http://b"  # least loaded first


def test_registry_crash_eviction_after_consecutive_failures():
    calls = {"n": 0}

    def fetch(url):
        if url == "http://dead":
            raise OSError("connection refused")
        return {"status": "ok"}

    reg = InstanceRegistry(("http://live", "http://dead"),
                           max_failures=2, fetch=fetch)
    evicted = []
    reg.on_evict(evicted.append)
    assert reg.poll_once() == 1
    # first failure: immediately unhealthy (skipped by placement)...
    assert [s.instance for s in reg.placeable()] == ["http://live"]
    assert "http://dead" in reg and not evicted
    # ...second consecutive failure: evicted, callback fired
    reg.poll_once()
    assert evicted == ["http://dead"]
    assert "http://dead" not in reg and len(reg) == 1


def test_registry_drain_and_rejoin():
    reg = InstanceRegistry(("http://a", "http://b"),
                           fetch=lambda url: {"status": "ok"})
    reg.poll_once()
    assert reg.drain("http://a")
    assert [s.instance for s in reg.placeable()] == ["http://b"]
    assert len(reg) == 2          # drained, not removed
    assert reg.join("http://a")   # rejoin reactivates
    assert [s.instance for s in reg.placeable()] == ["http://a",
                                                     "http://b"]
    assert not reg.drain("http://nope")


def test_imbalance_metric():
    assert imbalance({}) == 1.0
    assert imbalance({"a": 100, "b": 100}) == 1.0
    assert imbalance({"a": 300, "b": 100}) == 3.0
    assert imbalance({"a": 300, "b": 0}) == float("inf")


# ---------------------------------------------------------------------------
# the router over real (sim-backend) instances
# ---------------------------------------------------------------------------
def _build_instance(seed=0, time_scale=None, **cfg_kw):
    cfg = ServingConfig(strategy="scls", workers=2, slice_len=SLICE,
                        gamma=0.25, seed=seed, time_scale=time_scale,
                        **cfg_kw)
    return HTTPFrontend(cfg.build_sim().aio, port=0).start()


@pytest.fixture(scope="module")
def pair():
    fronts = [_build_instance(seed=i) for i in range(2)]
    yield fronts
    for f in fronts:
        f.shutdown()


def _request(host, port, method, path, body=None, timeout=60):
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    headers = {"Content-Type": "application/json"} if body is not None else {}
    conn.request(method, path,
                 json.dumps(body) if body is not None else None, headers)
    resp = conn.getresponse()
    raw = resp.read()
    conn.close()
    return resp, raw


def _rjson(router, method, path, body=None):
    resp, raw = _request(router.host, router.port, method, path, body)
    return resp, json.loads(raw)


def test_router_routes_and_reports(pair):
    with FleetRouter(tuple(f.url for f in pair), placer="round_robin",
                     poll_interval=0.2) as router:
        for i in range(4):
            resp, out = _rjson(router, "POST", "/v1/completions",
                               {"prompt": f"req {i}", "max_tokens": 8})
            assert resp.status == 200
            assert out["object"] == "text_completion"
        resp, health = _rjson(router, "GET", "/healthz")
        assert health["role"] == "router"
        assert health["n_instances"] == health["n_placeable"] == 2
        rows = {r["url"]: r for r in health["instances"]}
        assert set(rows) == {f.url for f in pair}
        # the /healthz placement vector flowed into the snapshots
        assert all("queue_delay_est" in r for r in rows.values())
        resp, stats = _rjson(router, "GET", "/metrics.json")
        # round robin: 4 requests alternate 2/2 across the instances
        assert sorted(stats["placements"].values()) == [2, 2]
        assert sum(stats["served_tokens"].values()) > 0
        resp, raw = _request(router.host, router.port, "GET", "/metrics")
        assert b"scls_fleet_requests_total" in raw
        resp, audit = _rjson(router, "GET", "/debug/placements")
        assert audit["n_recorded"] == 4
        assert all(ev["kind"] == "fleet_place" for ev in audit["events"])


def test_router_passes_429_retry_after_verbatim(pair):
    body = {"prompt": 512, "max_tokens": 900, "slo_ms": 1}
    direct_resp, _ = _request(pair[0].host, pair[0].port, "POST",
                              "/v1/completions", body)
    assert direct_resp.status == 429
    with FleetRouter((pair[0].url,), placer="round_robin",
                     poll_interval=5.0) as router:
        resp, out = _rjson(router, "POST", "/v1/completions", body)
        assert resp.status == 429
        assert out["error"]["type"] == "rate_limit_exceeded"
        # verbatim passthrough: byte-identical to the instance's header
        assert resp.getheader("Retry-After") == \
            direct_resp.getheader("Retry-After")


def test_router_join_endpoint_adds_instance(pair):
    extra = _build_instance(seed=5)
    try:
        with FleetRouter((pair[0].url,), placer="round_robin",
                         poll_interval=5.0) as router:
            resp, health = _rjson(router, "GET", "/healthz")
            assert health["n_instances"] == 1
            resp, out = _rjson(router, "POST", "/fleet/join",
                               {"url": extra.url})
            assert resp.status == 200 and out["healthy"]
            resp, health = _rjson(router, "GET", "/healthz")
            assert health["n_instances"] == health["n_placeable"] == 2
            # round robin now reaches the joined instance
            for i in range(2):
                resp, _ = _rjson(router, "POST", "/v1/completions",
                                 {"prompt": "after join",
                                  "max_tokens": 4})
                assert resp.status == 200
            _, stats = _rjson(router, "GET", "/metrics.json")
            assert extra.url in stats["placements"]
    finally:
        extra.shutdown()


def test_drain_finishes_inflight_sse_and_stops_placement():
    """Drain while an SSE stream is in flight: the stream runs to [DONE]
    on its own socket; every subsequent request lands elsewhere."""
    fronts = [_build_instance(seed=i, time_scale=4.0) for i in range(2)]
    try:
        with FleetRouter(tuple(f.url for f in fronts),
                         placer="round_robin",
                         poll_interval=0.2) as router:
            conn = http.client.HTTPConnection(router.host, router.port,
                                              timeout=60)
            conn.request("POST", "/v1/completions",
                         json.dumps({"prompt": "drain me",
                                     "max_tokens": 48, "stream": True}),
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            first = resp.fp.readline()   # stream is live
            assert first.startswith(b"data: ")
            # find where it was placed, drain that instance mid-stream
            _, audit = _rjson(router, "GET", "/debug/placements")
            placed = audit["events"][0]["instance"]
            r2, out = _rjson(router, "POST", "/fleet/drain",
                             {"url": placed})
            assert r2.status == 200
            rest = resp.read()
            conn.close()
            assert b"data: [DONE]" in first + rest   # finished cleanly
            other = next(f.url for f in fronts if f.url != placed)
            for i in range(3):
                r3, _ = _rjson(router, "POST", "/v1/completions",
                               {"prompt": "post drain", "max_tokens": 4})
                assert r3.status == 200
            _, stats = _rjson(router, "GET", "/metrics.json")
            assert stats["placements"][other] == 3
            assert stats["placements"].get(placed, 0) == 1
            _, health = _rjson(router, "GET", "/healthz")
            assert health["n_instances"] == 2       # drained, not gone
            assert health["n_placeable"] == 1
    finally:
        for f in fronts:
            f.shutdown()


def test_crash_evict_replaces_exactly_once():
    """Kill an instance: a request placed on it is re-placed exactly
    once on the survivor (no duplicate submission), and the dead
    instance is evicted from the registry."""
    fronts = [_build_instance(seed=i) for i in range(2)]
    by_url = {f.url: f for f in fronts}
    dead_url = sorted(by_url)[0]        # round robin hits this first
    live = by_url[sorted(by_url)[1]]
    try:
        with FleetRouter(tuple(by_url), placer="round_robin",
                         poll_interval=30.0, max_failures=1) as router:
            # hard-kill the listener (connection refused from now on)
            by_url[dead_url]._httpd.shutdown()
            by_url[dead_url]._httpd.server_close()
            resp, out = _rjson(router, "POST", "/v1/completions",
                               {"prompt": "crash path", "max_tokens": 8})
            assert resp.status == 200      # re-placed on the survivor
            _, stats = _rjson(router, "GET", "/metrics.json")
            assert stats["retries"] == 1
            assert stats["evictions"] == 1  # max_failures=1: instant
            # placements counts *decisions* (the failed attempt on the
            # dead instance included); tokens only flowed to the live one
            assert stats["placements"][live.url] == 1
            assert list(stats["served_tokens"]) == [live.url]
            _, health = _rjson(router, "GET", "/healthz")
            assert health["n_instances"] == 1
            # exactly-once: the fleet saw a single submission for the
            # single client request
            _, snap = _rjson(live, "GET", "/healthz")
            assert snap["n_submitted"] == 1
    finally:
        for f in fronts:
            try:
                f.shutdown()
            except Exception:
                pass


def test_session_pinning_and_migration_reprefill(pair):
    with FleetRouter(tuple(f.url for f in pair),
                     placer="retention_affinity",
                     poll_interval=0.2) as router:
        msgs = [{"role": "user", "content": "first turn of the chat"}]
        for turn in range(2):
            resp, out = _rjson(router, "POST", "/v1/chat/completions",
                               {"messages": msgs, "max_tokens": 8,
                                "session": 42})
            assert resp.status == 200
            msgs.append(out["choices"][0]["message"])
            msgs.append({"role": "user", "content": f"turn {turn + 2}"})
        _, audit = _rjson(router, "GET", "/debug/placements")
        turns = [ev for ev in audit["events"] if ev["session"] == 42]
        assert len(turns) == 2
        assert turns[0]["instance"] == turns[1]["instance"]   # pinned
        assert turns[1]["pinned"] == turns[0]["instance"]
        assert not turns[1]["migrated"]
        _, stats = _rjson(router, "GET", "/metrics.json")
        assert stats["reprefill_tokens"] == 0
        # drain the pinned instance: the next turn must migrate and pay
        # the history re-prefill (pinned-with-override)
        _rjson(router, "POST", "/fleet/drain",
               {"url": turns[0]["instance"]})
        resp, out = _rjson(router, "POST", "/v1/chat/completions",
                           {"messages": msgs, "max_tokens": 8,
                            "session": 42})
        assert resp.status == 200
        _, stats = _rjson(router, "GET", "/metrics.json")
        assert stats["migrations"] == 1
        assert stats["reprefill_tokens"] > 0
        _, audit = _rjson(router, "GET", "/debug/placements")
        last = audit["events"][-1]
        assert last["migrated"] and last["instance"] != turns[0]["instance"]
        # release through the router: pin + history bookkeeping drop
        resp, out = _rjson(router, "DELETE", "/v1/sessions/42")
        assert resp.status == 200 and out["released"]
        _, stats = _rjson(router, "GET", "/metrics.json")
        assert stats["sessions"] == 0


def test_router_503_when_no_instance(pair):
    with FleetRouter((), placer="least_load",
                     poll_interval=5.0) as router:
        resp, out = _rjson(router, "POST", "/v1/completions",
                           {"prompt": "nowhere to go", "max_tokens": 4})
        assert resp.status == 503
        assert resp.getheader("Retry-After") == "1"
        resp, _ = _rjson(router, "POST", "/fleet/drain",
                         {"url": "http://127.0.0.1:1"})
        assert resp.status == 404


# ---------------------------------------------------------------------------
# ServingConfig --http-host (fleet satellite)
# ---------------------------------------------------------------------------
def test_http_host_validated_and_parsed():
    with pytest.raises(ValueError, match="http_host"):
        ServingConfig(http_host="")
    with pytest.raises(ValueError, match="http_host"):
        ServingConfig(http_host="   ")
    cfg = ServingConfig.from_cli(["--http-host", "0.0.0.0",
                                  "--http-port", "0", "--backend", "sim"])
    assert cfg.http_host == "0.0.0.0" and cfg.http_port == 0
    assert ServingConfig().http_host == "127.0.0.1"
